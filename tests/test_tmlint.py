"""tools/tmlint: every rule pinned with positive + negative fixtures, the
baseline machinery, the dead-module report, and the clean run over the
real tree (which also pins that the genuine findings fixed in this PR —
blocking shutdown in ServingService.stop, per-chunk host sync in
TrainerEngine.evaluate — stay fixed).
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from tools.tmlint.core import Baseline, run_lint
from tools.tmlint.deadmod import dead_modules, render_report

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src" / "repro"


def lint_tree(tmp_path, files, **kw):
    """Write a fixture tree and lint it rooted at tmp_path."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return run_lint([tmp_path], root=tmp_path, **kw)


def rule_ids(result):
    return [f.rule for f in result.findings]


# --------------------------------------------------------------------------
# TM101: static_argnames hashability
# --------------------------------------------------------------------------


class TestTM101:
    UNFROZEN = """
        import dataclasses
        import jax

        @dataclasses.dataclass
        class Cfg:
            x: int = 0

        def f(a, cfg: Cfg):
            return a

        g = jax.jit(f, static_argnames=("cfg",))
        """

    def test_unfrozen_dataclass_static_arg_flagged(self, tmp_path):
        res = lint_tree(tmp_path, {"mod.py": self.UNFROZEN})
        assert rule_ids(res) == ["TM101"]
        assert "cfg" in res.findings[0].message

    def test_frozen_dataclass_is_clean(self, tmp_path):
        src = self.UNFROZEN.replace(
            "@dataclasses.dataclass", "@dataclasses.dataclass(frozen=True)"
        )
        res = lint_tree(tmp_path, {"mod.py": src})
        assert rule_ids(res) == []

    def test_explicit_hash_is_clean(self, tmp_path):
        src = self.UNFROZEN.replace(
            "x: int = 0",
            "x: int = 0\n"
            "            def __hash__(self):\n"
            "                return id(self)",
        )
        res = lint_tree(tmp_path, {"mod.py": src})
        assert rule_ids(res) == []

    def test_partial_decorator_form(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                import dataclasses
                import functools
                import jax

                @dataclasses.dataclass
                class Cfg:
                    x: int = 0

                @functools.partial(jax.jit, static_argnames=("cfg",))
                def f(a, cfg: Cfg):
                    return a
                """
            },
        )
        assert rule_ids(res) == ["TM101"]


# --------------------------------------------------------------------------
# TM102: donated-buffer reuse
# --------------------------------------------------------------------------


class TestTM102:
    def test_read_after_donation_flagged(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                import jax

                def g(x):
                    return x

                f = jax.jit(g, donate_argnums=(0,))

                def use(x):
                    y = f(x)
                    return x + y
                """
            },
        )
        assert rule_ids(res) == ["TM102"]
        assert "'x'" in res.findings[0].message

    def test_rebinding_result_is_clean(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                import jax

                def g(x):
                    return x

                f = jax.jit(g, donate_argnums=(0,))

                def use(x):
                    x = f(x)
                    return x
                """
            },
        )
        assert rule_ids(res) == []

    def test_builder_method_attr_pattern(self, tmp_path):
        # the TrainerEngine idiom: a builder method returns the donor,
        # the instance stores it, other methods call it
        src = """
            import jax

            class E:
                def __init__(self):
                    self._f = self._build()

                def _build(self):
                    return jax.jit(lambda m: m, donate_argnums=(0,))

                def bad(self, m):
                    out = self._f(m)
                    return m

                def good(self, m):
                    m = self._f(m)
                    return m
            """
        res = lint_tree(tmp_path, {"mod.py": src})
        assert rule_ids(res) == ["TM102"]
        assert res.findings[0].scope == "E.bad"


# --------------------------------------------------------------------------
# TM103: host syncs in hot-path modules
# --------------------------------------------------------------------------


class TestTM103:
    HOT = """
        import numpy as np

        def pull(x):
            return x.item()

        def loop(chunks, f):
            total = 0
            for c in chunks:
                total += int(f(c))
            return total

        def once(chunks, f):
            totals = [f(c) for c in chunks]
            return int(sum(totals))
        """

    def test_hot_module_syncs_flagged(self, tmp_path):
        res = lint_tree(tmp_path, {"serve/engine.py": self.HOT})
        assert rule_ids(res) == ["TM103", "TM103"]
        scopes = {f.scope for f in res.findings}
        # .item() and the int()-inside-loop; the single post-loop int(sum())
        # in once() is the sanctioned pattern and stays clean
        assert scopes == {"pull", "loop"}

    def test_cold_module_is_clean(self, tmp_path):
        res = lint_tree(tmp_path, {"other/util.py": self.HOT})
        assert rule_ids(res) == []

    def test_asarray_flagged_in_hot_module(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "train/tm_engine.py": """
                import numpy as np

                def to_host(x):
                    return np.asarray(x)
                """
            },
        )
        assert rule_ids(res) == ["TM103"]


# --------------------------------------------------------------------------
# TM201: pallas_call interpret plumbed
# --------------------------------------------------------------------------


class TestTM201:
    def test_missing_interpret_flagged(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                from jax.experimental import pallas as pl

                def _run(kernel, x):
                    return pl.pallas_call(kernel, grid=(1,))(x)
                """
            },
        )
        assert rule_ids(res) == ["TM201"]

    def test_interpret_kwarg_is_clean(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                from jax.experimental import pallas as pl

                def _run(kernel, x, interpret=False):
                    return pl.pallas_call(kernel, grid=(1,), interpret=interpret)(x)
                """
            },
        )
        assert rule_ids(res) == []


# --------------------------------------------------------------------------
# TM202: oracle registry coverage
# --------------------------------------------------------------------------


class TestTM202:
    REF = """
        def foo_ref(x):
            return x
        """
    WRAPPER = """
        from jax.experimental import pallas as pl

        {registry}

        def foo_pallas(x, interpret=False):
            return pl.pallas_call(_k, grid=(1,), interpret=interpret)(x)
        """

    def _tree(self, registry):
        return {
            "kernels/ref.py": self.REF,
            "kernels/foo.py": self.WRAPPER.format(registry=registry),
        }

    def test_registered_entry_point_is_clean(self, tmp_path):
        res = lint_tree(
            tmp_path, self._tree('PALLAS_ORACLES = {"foo_pallas": "foo_ref"}')
        )
        assert rule_ids(res) == []

    def test_missing_registry_flagged(self, tmp_path):
        res = lint_tree(tmp_path, self._tree("PALLAS_NOT_THE_REGISTRY = 1"))
        assert rule_ids(res) == ["TM202"]
        assert "foo_pallas" in res.findings[0].message

    def test_unregistered_entry_point_flagged(self, tmp_path):
        res = lint_tree(
            tmp_path, self._tree('PALLAS_ORACLES = {"other_pallas": "foo_ref"}')
        )
        assert rule_ids(res) == ["TM202"]

    def test_oracle_missing_from_ref_flagged(self, tmp_path):
        res = lint_tree(
            tmp_path, self._tree('PALLAS_ORACLES = {"foo_pallas": "nope_ref"}')
        )
        assert rule_ids(res) == ["TM202"]
        assert "nope_ref" in res.findings[0].message


# --------------------------------------------------------------------------
# TM203: grid helpers, not raw // and %
# --------------------------------------------------------------------------


class TestTM203:
    def test_raw_floordiv_in_wrapper_flagged(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "kernels/foo.py": """
                from jax.experimental import pallas as pl

                PALLAS_ORACLES = {"foo_pallas": "foo_ref"}

                def foo_pallas(x, block, interpret=False):
                    grid = (x.shape[0] // block,)
                    return pl.pallas_call(_k, grid=grid, interpret=interpret)(x)
                """
            },
        )
        assert rule_ids(res) == ["TM203"]

    def test_grid_blocks_helper_is_clean(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "kernels/foo.py": """
                from jax.experimental import pallas as pl
                from repro.kernels.shapes import grid_blocks

                PALLAS_ORACLES = {"foo_pallas": "foo_ref"}

                def foo_pallas(x, block, interpret=False):
                    grid = (grid_blocks(x.shape[0], block, axis="B"),)
                    return pl.pallas_call(_k, grid=grid, interpret=interpret)(x)
                """
            },
        )
        assert rule_ids(res) == []

    def test_division_in_kernel_body_not_flagged(self, tmp_path):
        # kernel bodies (no pallas_call of their own) may use // freely —
        # e.g. bit-index arithmetic
        res = lint_tree(
            tmp_path,
            {
                "kernels/foo.py": """
                def _foo_kernel(x_ref, o_ref):
                    o_ref[...] = x_ref[...] // 32
                """
            },
        )
        assert rule_ids(res) == []


# --------------------------------------------------------------------------
# TM301: blocking calls in async def
# --------------------------------------------------------------------------


class TestTM301:
    def test_blocking_shutdown_flagged(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                class S:
                    async def stop(self):
                        self._executor.shutdown(wait=True)
                """
            },
        )
        assert rule_ids(res) == ["TM301"]
        assert res.findings[0].scope == "S.stop"

    def test_to_thread_shutdown_is_clean(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                import asyncio

                class S:
                    async def stop(self):
                        await asyncio.to_thread(self._executor.shutdown, True)
                """
            },
        )
        assert rule_ids(res) == []

    def test_awaited_primitives_and_str_join_clean(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                async def run(sem, parts):
                    await sem.acquire()
                    return ", ".join(parts)
                """
            },
        )
        assert rule_ids(res) == []

    def test_time_sleep_and_bare_join_flagged(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                import time

                async def run(worker):
                    time.sleep(1)
                    worker.join()
                """
            },
        )
        assert sorted(rule_ids(res)) == ["TM301", "TM301"]

    def test_sync_helper_inside_async_not_flagged(self, tmp_path):
        # nested sync defs/lambdas run off-loop via executors; their
        # blocking calls are not event-loop stalls
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                async def run(loop, ex, fut):
                    def work():
                        return fut.result()
                    return await loop.run_in_executor(ex, work)
                """
            },
        )
        assert rule_ids(res) == []


# --------------------------------------------------------------------------
# TM302: scheduler encapsulation
# --------------------------------------------------------------------------


class TestTM302:
    def test_external_poke_flagged(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                def drain(sched):
                    sched._queues.clear()
                    return sched._depths
                """
            },
        )
        assert sorted(rule_ids(res)) == ["TM302", "TM302"]

    def test_self_access_is_clean(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                class MicrobatchScheduler:
                    def depth(self, model):
                        return self._depths.get(model, 0)
                """
            },
        )
        assert rule_ids(res) == []


# --------------------------------------------------------------------------
# TM303: ServingEngine registry mutated only by lifecycle methods
# --------------------------------------------------------------------------


class TestTM303:
    def test_external_subscript_store_flagged_once(self, tmp_path):
        # one finding per statement — the store must not also fire the
        # bare-attribute-read branch
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                def sneak(engine, entry):
                    engine._servables["m"] = entry
                """
            },
        )
        assert rule_ids(res) == ["TM303"]
        assert "register/swap/rollback" in res.findings[0].message

    def test_external_delete_and_pop_flagged(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                def evict(engine):
                    del engine._servables["m"]
                    engine._servables.pop("n", None)
                """
            },
        )
        assert sorted(rule_ids(res)) == ["TM303", "TM303"]

    def test_external_read_flagged(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                def peek(engine):
                    return engine._servables["m"]
                """
            },
        )
        assert rule_ids(res) == ["TM303"]
        assert "servable()" in res.findings[0].message

    def test_lifecycle_methods_are_clean(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                class ServingEngine:
                    def __init__(self):
                        self._servables = {}

                    def register(self, name, entry):
                        self._servables[name] = entry

                    def swap(self, name, entry):
                        self._servables[name] = entry

                    def rollback(self, name):
                        self._servables[name] = self._servables[name].prev

                    def models(self):
                        return sorted(self._servables)
                """
            },
        )
        assert rule_ids(res) == []

    def test_self_mutation_outside_lifecycle_methods_flagged(self, tmp_path):
        # even the engine's own helpers may not install weights directly —
        # only register/swap/rollback hold the lock + stamp contract
        res = lint_tree(
            tmp_path,
            {
                "mod.py": """
                class ServingEngine:
                    def install_unsafe(self, name, entry):
                        self._servables[name] = entry

                    def reset(self):
                        self._servables.clear()
                """
            },
        )
        assert sorted(rule_ids(res)) == ["TM303", "TM303"]
        scopes = {f.scope for f in res.findings}
        assert scopes == {
            "ServingEngine.install_unsafe",
            "ServingEngine.reset",
        }


class TestTM304:
    def test_swallowed_broad_except_flagged(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "repro/serve/worker.py": """
                def drain(queue):
                    try:
                        queue.flush()
                    except Exception:
                        pass
                """
            },
        )
        assert rule_ids(res) == ["TM304"]
        assert "sink" in res.findings[0].message

    def test_bare_except_and_broad_tuple_flagged(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "repro/serve/worker.py": """
                def a(x):
                    try:
                        x()
                    except:
                        return None

                def b(x):
                    try:
                        x()
                    except (ValueError, Exception):
                        return None
                """
            },
        )
        assert sorted(rule_ids(res)) == ["TM304", "TM304"]

    def test_reraise_and_future_resolution_are_clean(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "repro/serve/worker.py": """
                def a(x):
                    try:
                        x()
                    except Exception:
                        raise

                def b(x, fut):
                    try:
                        x()
                    except Exception as e:
                        if not fut.done():
                            fut.set_exception(e)
                """
            },
        )
        assert rule_ids(res) == []

    def test_stats_and_health_sinks_are_clean(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "repro/serve/worker.py": """
                def a(self, x):
                    try:
                        x()
                    except Exception as e:
                        self._health.note_fault(e)

                def b(self, x):
                    try:
                        x()
                    except Exception:
                        self.stats.rejected += 1
                """
            },
        )
        assert rule_ids(res) == []

    def test_sink_inside_nested_def_does_not_count(self, tmp_path):
        # A handler that only *defines* a callback touching stats has not
        # recorded anything yet — the fault is still swallowed.
        res = lint_tree(
            tmp_path,
            {
                "repro/serve/worker.py": """
                def a(self, x):
                    try:
                        x()
                    except Exception:
                        def later():
                            self.stats.faults += 1
                        return later
                """
            },
        )
        assert rule_ids(res) == ["TM304"]

    def test_typed_except_and_non_serve_modules_exempt(self, tmp_path):
        res = lint_tree(
            tmp_path,
            {
                "repro/serve/worker.py": """
                def a(x):
                    try:
                        x()
                    except ValueError:
                        return None
                """,
                "repro/train/loop.py": """
                def b(x):
                    try:
                        x()
                    except Exception:
                        pass
                """,
            },
        )
        assert rule_ids(res) == []


# --------------------------------------------------------------------------
# Baseline machinery
# --------------------------------------------------------------------------


class TestBaseline:
    FILES = {
        "serve/engine.py": """
            def pull(x):
                return x.item()
            """
    }

    def test_baseline_suppresses_fingerprint(self, tmp_path):
        first = lint_tree(tmp_path, self.FILES)
        assert rule_ids(first) == ["TM103"]
        f = first.findings[0]
        bl = Baseline(
            [
                {
                    "rule": f.rule,
                    "path": f.path,
                    "scope": f.scope,
                    "line_text": f.line_text,
                    "justification": "fixture: accepted for the test",
                }
            ]
        )
        second = run_lint([tmp_path], root=tmp_path, baseline=bl)
        assert second.ok
        assert rule_ids(second) == []
        assert [s.rule for s in second.suppressed] == ["TM103"]
        assert second.stale_baseline == []

    def test_baseline_is_line_number_free(self, tmp_path):
        first = lint_tree(tmp_path, self.FILES)
        f = first.findings[0]
        bl = Baseline(
            [
                {
                    "rule": f.rule,
                    "path": f.path,
                    "scope": f.scope,
                    "line_text": f.line_text,
                    "justification": "fixture",
                }
            ]
        )
        shifted = {
            "serve/engine.py": """
            # a new comment shifts every line number
            UNRELATED = 1


            def pull(x):
                return x.item()
            """
        }
        res = lint_tree(tmp_path, shifted, baseline=bl)
        assert res.ok and [s.rule for s in res.suppressed] == ["TM103"]

    def test_entry_without_justification_rejected(self):
        with pytest.raises(ValueError, match="justification"):
            Baseline(
                [
                    {
                        "rule": "TM103",
                        "path": "p.py",
                        "scope": "f",
                        "line_text": "x.item()",
                        "justification": "   ",
                    }
                ]
            )

    def test_stale_entries_reported(self, tmp_path):
        bl = Baseline(
            [
                {
                    "rule": "TM103",
                    "path": "serve/engine.py",
                    "scope": "gone",
                    "line_text": "y.item()",
                    "justification": "covers code that was deleted",
                }
            ]
        )
        res = lint_tree(tmp_path, {"serve/engine.py": "X = 1\n"}, baseline=bl)
        assert res.ok
        assert [e["scope"] for e in res.stale_baseline] == ["gone"]

    def test_committed_baseline_entries_all_live(self):
        """Every committed suppression still matches a finding — the
        baseline cannot silently rot."""
        bl = Baseline.load(REPO / "tools" / "tmlint" / "baseline.json")
        res = run_lint([SRC], root=REPO, baseline=bl)
        assert res.ok, [f.render() for f in res.findings]
        assert res.stale_baseline == [], res.stale_baseline


# --------------------------------------------------------------------------
# The real tree: clean run + the fixed findings stay fixed
# --------------------------------------------------------------------------


class TestRealTree:
    def test_cli_clean_on_committed_tree(self):
        proc = subprocess.run(
            [sys.executable, "-m", "tools.tmlint", "src/repro"],
            cwd=REPO,
            capture_output=True,
            text=True,
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr

    def test_cli_nonzero_on_finding(self, tmp_path):
        bad = tmp_path / "serve" / "engine.py"
        bad.parent.mkdir(parents=True)
        bad.write_text("def pull(x):\n    return x.item()\n")
        proc = subprocess.run(
            [sys.executable, "-m", "tools.tmlint", "--no-baseline", str(tmp_path)],
            cwd=REPO,
            capture_output=True,
            text=True,
        )
        assert proc.returncode == 1
        assert "TM103" in proc.stdout

    def test_service_stop_stays_nonblocking(self):
        """Regression pin for the fixed finding: ServingService.stop used
        to join its executors on the event loop; TM301 must stay clean on
        the whole serving service module."""
        res = run_lint(
            [SRC / "serve" / "service.py"], root=REPO, baseline=Baseline.empty()
        )
        assert [f.rule for f in res.findings] == []

    def test_trainer_evaluate_stays_single_sync(self):
        """Regression pin for the fixed finding: TrainerEngine.evaluate
        used to int() every chunk inside the dispatch loop.  No unbaselined
        TM103 may reappear in tm_engine.py, and in particular nothing in
        evaluate()."""
        bl = Baseline.load(REPO / "tools" / "tmlint" / "baseline.json")
        res = run_lint([SRC / "train" / "tm_engine.py"], root=REPO, baseline=bl)
        assert res.ok, [f.render() for f in res.findings]
        eval_findings = [
            f
            for f in res.findings + res.suppressed
            if f.scope == "TrainerEngine.evaluate"
        ]
        assert eval_findings == []

    def test_engine_has_no_duplicate_defs(self):
        """Regression pin for the fixed finding: ServingEngine briefly had
        two `servable` methods (the first silently dead)."""
        import ast as ast_mod

        tree = ast_mod.parse((SRC / "serve" / "engine.py").read_text())
        for node in ast_mod.walk(tree):
            if isinstance(node, ast_mod.ClassDef):
                names = [
                    b.name
                    for b in node.body
                    if isinstance(b, (ast_mod.FunctionDef, ast_mod.AsyncFunctionDef))
                ]
                dupes = {n for n in names if names.count(n) > 1}
                assert not dupes, f"{node.name} redefines {sorted(dupes)}"

    def test_kernel_modules_all_registered(self):
        """TM202 over the real kernels package: every pallas entry point
        registered, every oracle present in ref.py."""
        res = run_lint([SRC / "kernels"], root=REPO, baseline=Baseline.empty())
        assert res.ok, [f.render() for f in res.findings]


# --------------------------------------------------------------------------
# Dead-module report
# --------------------------------------------------------------------------


class TestDeadModules:
    def test_synthetic_orphan_detected(self, tmp_path):
        src = tmp_path / "src"
        (src / "repro" / "serve").mkdir(parents=True)
        (src / "repro" / "__init__.py").write_text("")
        (src / "repro" / "serve" / "__init__.py").write_text("")
        (src / "repro" / "serve" / "engine.py").write_text(
            "from repro import used\n"
        )
        (src / "repro" / "used.py").write_text("X = 1\n")
        (src / "repro" / "orphan.py").write_text("Y = 2\n")
        (tmp_path / "tests").mkdir()
        (tmp_path / "benchmarks").mkdir()
        result = dead_modules(
            src, tmp_path / "tests", tmp_path / "benchmarks"
        )
        assert result["dead"] == ["repro.orphan"]
        assert result["bench_only"] == []

    def test_bench_only_annotated(self, tmp_path):
        src = tmp_path / "src"
        (src / "repro" / "serve").mkdir(parents=True)
        (src / "repro" / "__init__.py").write_text("")
        (src / "repro" / "serve" / "__init__.py").write_text("")
        (src / "repro" / "benchy.py").write_text("Z = 3\n")
        (tmp_path / "tests").mkdir()
        (tmp_path / "benchmarks").mkdir()
        (tmp_path / "benchmarks" / "bench_z.py").write_text(
            "from repro import benchy\n"
        )
        result = dead_modules(src, tmp_path / "tests", tmp_path / "benchmarks")
        assert result["bench_only"] == ["repro.benchy"]
        assert "repro.benchy" not in result["dead"]

    def test_committed_report_is_fresh(self):
        """tools/tmlint/REPORT.md matches what the analysis produces now;
        regenerate with `python -m tools.tmlint --dead-modules`."""
        want = render_report(
            dead_modules(REPO / "src", REPO / "tests", REPO / "benchmarks")
        )
        have = (REPO / "tools/tmlint/REPORT.md").read_text()
        assert have == want


# --------------------------------------------------------------------------
# --prune-baseline: rewrite the baseline minus stale entries
# --------------------------------------------------------------------------


class TestPruneBaseline:
    STALE = {
        "rule": "TM103",
        "path": "serve/engine.py",
        "scope": "gone",
        "line_text": "y.item()",
        "justification": "covers code that was deleted",
    }

    def _fixture(self, tmp_path):
        """A tree with one real finding; returns its live baseline entry."""
        fx = tmp_path / "serve" / "engine.py"
        fx.parent.mkdir(parents=True)
        fx.write_text("def pull(x):\n    return x.item()\n")
        res = run_lint([fx], root=tmp_path, baseline=Baseline.empty())
        assert len(res.findings) == 1
        rule, path, scope, line_text = res.findings[0].fingerprint()
        return {
            "rule": rule,
            "path": path,
            "scope": scope,
            "line_text": line_text,
            "justification": "accepted fixture finding",
        }

    def test_prune_removes_only_stale_entries(self, tmp_path, monkeypatch):
        from tools.tmlint.__main__ import main

        live = self._fixture(tmp_path)
        bl_path = tmp_path / "baseline.json"
        bl_path.write_text(
            json.dumps({"version": 1, "suppressions": [live, self.STALE]})
        )
        monkeypatch.chdir(tmp_path)
        rc = main(["serve", "--baseline", str(bl_path), "--prune-baseline"])
        assert rc == 0  # the real finding is suppressed by the live entry
        data = json.loads(bl_path.read_text())
        assert data["version"] == 1
        assert [e["scope"] for e in data["suppressions"]] == [live["scope"]]

    def test_prune_noop_when_nothing_stale(self, tmp_path, monkeypatch):
        from tools.tmlint.__main__ import main

        live = self._fixture(tmp_path)
        bl_path = tmp_path / "baseline.json"
        before = json.dumps({"version": 1, "suppressions": [live]})
        bl_path.write_text(before)
        monkeypatch.chdir(tmp_path)
        rc = main(["serve", "--baseline", str(bl_path), "--prune-baseline"])
        assert rc == 0
        assert bl_path.read_text() == before  # untouched, formatting intact

    def test_live_entries_complements_stale(self, tmp_path):
        live = self._fixture(tmp_path)
        bl = Baseline([live, self.STALE])
        res = run_lint([tmp_path / "serve"], root=tmp_path, baseline=bl)
        assert res.ok
        assert [e["scope"] for e in bl.stale_entries()] == ["gone"]
        assert [e["scope"] for e in bl.live_entries()] == [live["scope"]]
