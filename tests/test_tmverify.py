"""tools/tmverify: every rule pinned with positive + negative fixtures,
the waiver baseline machinery, the committed-report freshness gate, and
the clean full run over the real serve/train paths (the acceptance gate:
every registered (path x form x bucket) step plus the trainer epoch step
verifies under TM401-TM405).
"""

import dataclasses
import json
import subprocess
import sys
import types
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from tools.tmverify.analyses import (
    aliased_output_count,
    audit_registry_path,
    check_donation,
    check_host_transfers,
    forbidden_primitives,
)
from tools.tmverify.core import Baseline, Finding, VerifyResult
from tools.tmverify.intervals import Interval, analyze_fn, dtype_interval
from tools.tmverify.pallas_check import PallasCapture, audit_capture
from tools.tmverify.report import render_report
from tools.tmverify.run import run_verify
from tools.tmverify.targets import StepTarget, VerifyConfig, buckets_for

REPO = Path(__file__).resolve().parents[1]
BASELINE_PATH = REPO / "tools/tmverify/baseline.json"
REPORT_PATH = REPO / "tools/tmverify/REPORT.md"


def fresh_result() -> VerifyResult:
    return VerifyResult(
        findings=[], suppressed=[], stale_baseline=[], targets=[], checks=0
    )


@pytest.fixture(scope="module")
def verify_run():
    """One full verify of the committed tree, shared by the positive
    tests (the run is the expensive part: ~100 traces + one compile)."""
    vcfg = VerifyConfig()
    baseline = Baseline.load(BASELINE_PATH)
    return run_verify(vcfg, baseline), vcfg, baseline


# --------------------------------------------------------------------------
# Full-run acceptance
# --------------------------------------------------------------------------


class TestFullRun:
    def test_committed_tree_is_clean(self, verify_run):
        result, _, _ = verify_run
        assert result.ok, [f.render() for f in result.findings]
        assert not result.stale_baseline

    def test_enumerates_every_path_form_bucket(self, verify_run):
        from repro.serve.paths import available_paths

        result, vcfg, _ = verify_run
        serve = [t for t in result.targets if t.startswith("serve:")]
        paths = available_paths()
        n_buckets = len(buckets_for(vcfg.max_batch))
        assert len(serve) == len(paths) * 2 * n_buckets
        for name in paths:
            for form in ("literals", "raw"):
                for b in buckets_for(vcfg.max_batch):
                    assert f"serve:{name}:{form}:b{b}" in serve
        assert "train:epoch" in result.targets

    def test_every_rule_ran(self, verify_run):
        result, _, _ = verify_run
        assert sorted(result.summary) == [
            "TM401", "TM402", "TM403", "TM404", "TM405"
        ]
        assert result.checks > 100

    def test_committed_report_is_fresh(self, verify_run):
        result, vcfg, _ = verify_run
        assert render_report(result, vcfg) == REPORT_PATH.read_text(
            encoding="utf-8"
        ), (
            "tools/tmverify/REPORT.md is stale; regenerate with "
            "`python -m tools.tmverify src/repro --report > "
            "tools/tmverify/REPORT.md`"
        )

    def test_cli_list_rules(self):
        proc = subprocess.run(
            [sys.executable, "-m", "tools.tmverify", "--list-rules"],
            cwd=REPO, capture_output=True, text=True,
        )
        assert proc.returncode == 0
        for rule in ("TM401", "TM402", "TM403", "TM404", "TM405"):
            assert rule in proc.stdout


# --------------------------------------------------------------------------
# TM401 donation audit
# --------------------------------------------------------------------------


class TestTM401:
    def _target(self, fn, arg, donated: int, kind="serve") -> StepTarget:
        tr = fn.trace(arg)
        return StepTarget(
            name="fixture:donate", kind=kind, path_name=None, form=None,
            bucket=None, jaxpr=tr.jaxpr, donated_leaves=donated, traced=tr,
        )

    def test_honoured_donation_passes(self):
        f = jax.jit(lambda x: x + 1, donate_argnums=(0,))
        t = self._target(f, jnp.zeros((8,), jnp.float32), donated=1)
        assert aliased_output_count(t.lowered_text()) == 1
        result = fresh_result()
        check_donation([t], result, Baseline.empty())
        assert result.ok

    def test_dropped_donation_flagged(self):
        # Donated input cannot alias the scalar output: XLA silently
        # drops the donation — exactly what TM401 exists to catch.
        f = jax.jit(lambda x: x.sum(), donate_argnums=(0,))
        t = self._target(f, jnp.zeros((8,), jnp.float32), donated=1)
        assert aliased_output_count(t.lowered_text()) == 0
        result = fresh_result()
        check_donation([t], result, Baseline.empty())
        assert [f_.rule for f_ in result.findings] == ["TM401"]
        assert result.findings[0].key == "dropped:0of1"


# --------------------------------------------------------------------------
# TM402 host-transfer freedom
# --------------------------------------------------------------------------


class TestTM402:
    def test_pure_graph_passes(self):
        closed = jax.make_jaxpr(lambda x: (x * 2).sum())(jnp.ones(4))
        assert forbidden_primitives(closed.jaxpr) == []

    def test_callback_flagged(self):
        def bad(x):
            jax.debug.print("x={x}", x=x)
            return x * 2

        closed = jax.make_jaxpr(bad)(jnp.ones(4))
        bad_prims = forbidden_primitives(closed.jaxpr)
        assert bad_prims and all("callback" in p for p in bad_prims)

        t = StepTarget(
            name="fixture:callback", kind="serve", path_name=None,
            form=None, bucket=None, jaxpr=closed, donated_leaves=0,
            traced=None,
        )
        result = fresh_result()
        check_host_transfers([t], result, Baseline.empty())
        assert [f.rule for f in result.findings] == ["TM402"]

    def test_nested_jaxprs_are_walked(self):
        # The callback hides inside a jitted sub-call; the walk must
        # recurse through the pjit body to see it.
        inner = jax.jit(lambda x: jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct((4,), jnp.float32), x
        ))
        closed = jax.make_jaxpr(lambda x: inner(x) + 1)(jnp.ones(4))
        assert forbidden_primitives(closed.jaxpr)


# --------------------------------------------------------------------------
# TM403 recompile-key audit
# --------------------------------------------------------------------------


def fake_path(**kw):
    defaults = dict(
        name="fixture", input_form="packed", tunable=((),), fallback=None,
        ingress_spec=lambda spec: spec,
    )
    defaults.update(kw)
    ns = types.SimpleNamespace(**{
        k: v for k, v in defaults.items() if k != "ingress_spec"
    })
    ns.ingress_spec = defaults["ingress_spec"]
    return ns


class TestTM403:
    SPEC = None  # a hashable stand-in is enough for the fixtures

    def audit(self, path, cap=128, n_buckets=9):
        return audit_registry_path(
            path, self.SPEC, n_buckets=n_buckets, n_forms=2, cap=cap
        )

    def test_real_registry_is_bounded(self):
        from repro.core.patches import PatchSpec
        from repro.serve.paths import available_paths, get_path

        spec = PatchSpec(8, 8, 4, 4)
        for name in available_paths():
            findings, card = audit_registry_path(
                get_path(name), spec, n_buckets=9, n_forms=2, cap=128
            )
            assert findings == [], [f.render() for f in findings]
            assert card <= 128

    def test_list_tunable_flagged(self):
        findings, _ = self.audit(fake_path(tunable=[()]))
        assert any(f.key == "tunable:not-tuple" for f in findings)

    def test_unhashable_param_value_flagged(self):
        findings, _ = self.audit(
            fake_path(tunable=((("block_b", [8, 16]),),))
        )
        assert any(f.key == "params:0:unhashable" for f in findings)

    def test_malformed_param_set_flagged(self):
        findings, _ = self.audit(fake_path(tunable=(("block_b", 16),)))
        assert any("malformed" in f.key for f in findings)

    def test_unhashable_ingress_spec_flagged(self):
        findings, _ = self.audit(fake_path(ingress_spec=lambda spec: []))
        assert any(f.key == "ingress:unhashable" for f in findings)

    def test_unregistered_fallback_flagged(self):
        findings, _ = self.audit(fake_path(fallback="no_such_path"))
        assert any(f.key == "fallback:unregistered" for f in findings)

    def test_unbounded_cardinality_flagged(self):
        many = tuple(((("block_b", 8 * i),)) for i in range(1, 30))
        findings, card = self.audit(fake_path(tunable=many), cap=100)
        assert card == 9 * 29
        assert any(f.key.startswith("cardinality:") for f in findings)


# --------------------------------------------------------------------------
# TM404 interval analysis
# --------------------------------------------------------------------------


class TestTM404:
    S = jax.ShapeDtypeStruct

    def test_int32_class_sum_proven_safe(self):
        # The envelope proof in miniature: 127 * C ones into int32.
        def f(fired, w):
            return jax.lax.dot_general(
                fired.astype(jnp.int8), w.astype(jnp.int8),
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32,
            )

        findings, stats = analyze_fn(
            f, [self.S((4, 1024), jnp.uint8), self.S((64, 1024), jnp.int8)],
            [Interval(0, 1), Interval(-127, 127)], "fixture:class_sum",
        )
        assert findings == []
        assert stats.widest_int == Interval(-130048, 130048)

    def test_int8_accumulator_overflow_flagged(self):
        findings, _ = analyze_fn(
            lambda x: jnp.sum(x, axis=0, dtype=jnp.int8),
            [self.S((300,), jnp.int8)], [Interval(0, 1)], "fixture:sum8",
        )
        assert [f.rule for f in findings] == ["TM404"]
        assert "overflows int8" in findings[0].message

    def test_narrowing_convert_flagged(self):
        findings, _ = analyze_fn(
            lambda x: x.astype(jnp.int8),
            [self.S((4,), jnp.int32)], [Interval(0, 300)], "fixture:narrow",
        )
        assert [f.key.endswith("narrowing") for f in findings] == [True]

    def test_fp32_exactness_loss_flagged(self):
        findings, _ = analyze_fn(
            lambda x: x.astype(jnp.float32),
            [self.S((4,), jnp.int32)], [Interval(0, 1 << 25)],
            "fixture:inexact",
        )
        assert [f.rule for f in findings] == ["TM404"]
        assert "exact-integer bound 16777216" in findings[0].message

    def test_popcount_chain_bound(self):
        # sum of W=256 popcounts of uint32 words: proven <= 8192.
        def f(w):
            return jnp.sum(
                jax.lax.population_count(w).astype(jnp.int32), axis=-1
            )

        findings, stats = analyze_fn(
            f, [self.S((4, 256), jnp.uint32)],
            [Interval(0, (1 << 32) - 1)], "fixture:popcount",
        )
        assert findings == []

    def test_dtype_interval(self):
        assert dtype_interval(jnp.int8) == Interval(-128, 127)
        assert dtype_interval(jnp.uint32) == Interval(0, (1 << 32) - 1)
        assert dtype_interval(jnp.float32) == Interval(-(1 << 24), 1 << 24)


# --------------------------------------------------------------------------
# TM405 Pallas grid/VMEM audit
# --------------------------------------------------------------------------


def block_spec(shape, index_map):
    return types.SimpleNamespace(block_shape=shape, index_map=index_map)


class TestTM405:
    def test_exact_cover_passes(self):
        cap = PallasCapture(
            label="fixture", grid=(3, 2),
            in_specs=[block_spec((8, 128), lambda i, j: (i, j))],
            out_specs=[], out_shapes=[], scratch=[],
            operand_shapes=[(24, 256)],
        )
        findings, footprint = audit_capture(cap, budget=16 << 20)
        assert findings == []
        assert footprint == 2 * 8 * 128 * 4

    def test_undersized_grid_flagged(self):
        # 24 rows need 3 blocks of 8; a grid of 2 drops the last tile.
        cap = PallasCapture(
            label="fixture", grid=(2,),
            in_specs=[block_spec((8, 128), lambda i: (i, 0))],
            out_specs=[], out_shapes=[], scratch=[],
            operand_shapes=[(24, 128)],
        )
        findings, _ = audit_capture(cap, budget=16 << 20)
        assert any(f.key == "in0:axis0:cover" for f in findings)

    def test_unpadded_extent_flagged(self):
        cap = PallasCapture(
            label="fixture", grid=(2,),
            in_specs=[block_spec((8, 128), lambda i: (i, 0))],
            out_specs=[], out_shapes=[], scratch=[],
            operand_shapes=[(12, 128)],
        )
        findings, _ = audit_capture(cap, budget=16 << 20)
        assert any(f.key == "in0:axis0:unpadded" for f in findings)

    def test_over_budget_footprint_flagged(self):
        cap = PallasCapture(
            label="fixture", grid=(1,),
            in_specs=[block_spec((4096, 4096), lambda i: (0, 0))],
            out_specs=[], out_shapes=[],
            scratch=[((4096, 4096), jnp.int32)],
            operand_shapes=[(4096, 4096)],
        )
        findings, footprint = audit_capture(cap, budget=16 << 20)
        assert any(f.key.startswith("vmem:") for f in findings)
        assert footprint == 3 * 4096 * 4096 * 4

    def test_clamped_blocks_match_dispatch(self):
        # clamp_block is shared with ops.py so the audit sees dispatch's
        # real block shapes: a 3-row batch never pays for a 128-row tile.
        from repro.kernels.shapes import clamp_block

        assert clamp_block(128, 3, 8) == 8
        assert clamp_block(8, 4096, 8) == 8
        assert clamp_block(128, 1024, 128) == 128


# --------------------------------------------------------------------------
# Baseline machinery
# --------------------------------------------------------------------------


class TestBaseline:
    FINDING = Finding("TM401", "serve:x:raw:b8", "dropped:0of1", "msg")

    def test_waiver_suppresses(self):
        b = Baseline([{
            "rule": "TM401", "target": "serve:x:raw:b8",
            "key": "dropped:0of1", "justification": "accepted for reasons",
        }])
        result = fresh_result()
        result.add(b, self.FINDING)
        assert result.ok
        assert len(result.suppressed) == 1
        assert b.stale_entries() == []

    def test_missing_justification_rejected(self):
        with pytest.raises(ValueError, match="justification"):
            Baseline([{
                "rule": "TM401", "target": "t", "key": "k",
                "justification": "  ",
            }])

    def test_stale_waiver_reported(self):
        b = Baseline([{
            "rule": "TM405", "target": "pallas:gone", "key": "vmem:1",
            "justification": "kernel was removed",
        }])
        result = fresh_result()
        result.add(b, self.FINDING)  # does not match the waiver
        assert not result.ok
        assert len(b.stale_entries()) == 1

    def test_committed_baseline_loads(self):
        data = json.loads(BASELINE_PATH.read_text(encoding="utf-8"))
        assert data["version"] == 1
        Baseline.load(BASELINE_PATH)  # justification contract holds


# --------------------------------------------------------------------------
# Target enumeration helpers
# --------------------------------------------------------------------------


class TestTargets:
    def test_buckets_cover_pow2_range(self):
        assert buckets_for(32) == (1, 2, 4, 8, 16, 32)
        assert buckets_for(1) == (1,)
        assert buckets_for(256) == (1, 2, 4, 8, 16, 32, 64, 128, 256)
