"""ConvCoTM training: invariants (hypothesis) + learning integration."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.cotm import CoTMConfig, TA_HALF, WEIGHT_MAX, WEIGHT_MIN, init_model
from repro.core.patches import PatchSpec
from repro.core.train import accuracy, sample_deltas, update_batch
from repro.data import booleanize_split, noisy_xor_2d, synthetic_glyphs

SPEC_XOR = PatchSpec(image_x=4, image_y=4, window_x=2, window_y=2)


def _cfg(**kw):
    base = dict(n_clauses=12, n_classes=2, patch=SPEC_XOR, T=15, s=3.0)
    base.update(kw)
    return CoTMConfig(**base)


class TestInvariants:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), steps=st.integers(1, 3))
    def test_ta_states_and_weights_bounded(self, seed, steps):
        cfg = _cfg()
        key = jax.random.PRNGKey(seed)
        model = init_model(key, cfg)
        imgs = (jax.random.uniform(key, (16, 4, 4)) > 0.5).astype(jnp.uint8)
        labels = jax.random.randint(key, (16,), 0, 2)
        for _ in range(steps):
            key, k = jax.random.split(key)
            model = update_batch(k, model, imgs, labels, cfg)
        ta = np.asarray(model.ta_state)
        w = np.asarray(model.weights)
        assert ta.min() >= 0 and ta.max() <= 2 * TA_HALF - 1
        assert w.min() >= WEIGHT_MIN and w.max() <= WEIGHT_MAX

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_literal_budget_blocks_new_includes(self, seed):
        """While a clause holds >= budget includes, the per-sample TA delta
        may not push any NON-included literal upward (the IJCAI'23 [42]
        growth gate).  (Includes can lawfully regrow after Type-Ib decay
        drops the clause below budget — so the gate is tested directly on
        the deltas, not on multi-step trajectories.)"""
        cfg = _cfg(max_included_literals=3, s=1.5)
        key = jax.random.PRNGKey(seed)
        model = init_model(key, cfg)
        nlit = cfg.n_literals
        # 4 includes (over budget), everything else one step below include.
        ta = np.full((cfg.n_clauses, nlit), TA_HALF - 1, np.uint8)
        ta[:, :4] = TA_HALF
        model.ta_state = jnp.asarray(ta)
        include = np.asarray(model.include).astype(bool)
        img = (jax.random.uniform(key, (4, 4)) > 0.5).astype(jnp.uint8)
        for lbl in (0, 1):
            key, k = jax.random.split(key)
            ta_d, _ = sample_deltas(k, model, img, jnp.int32(lbl), cfg)
            ta_d = np.asarray(ta_d)
            # positive TA movement only on already-included literals
            assert not (ta_d[~include] > 0).any()

    def test_scan_mode_matches_semantics(self):
        """scan (sequential) mode runs and stays within bounds; with a
        single-sample batch it must equal batch mode exactly."""
        cfg = _cfg()
        key = jax.random.PRNGKey(0)
        model = init_model(key, cfg)
        img = (jax.random.uniform(key, (1, 4, 4)) > 0.5).astype(jnp.uint8)
        lbl = jnp.array([1])
        m_b = update_batch(key, model, img, lbl, cfg, mode="batch")
        m_s = update_batch(key, model, img, lbl, cfg, mode="scan")
        np.testing.assert_array_equal(
            np.asarray(m_b.ta_state), np.asarray(m_s.ta_state)
        )
        np.testing.assert_array_equal(
            np.asarray(m_b.weights), np.asarray(m_s.weights)
        )

    def test_update_probability_saturation(self):
        """With v_y clipped at +T, the target-class update prob is 0 — a
        fully-confident sample must produce (almost) no Type-I include
        growth from the target side."""
        cfg = _cfg(T=1)
        key = jax.random.PRNGKey(3)
        model = init_model(key, cfg)
        # force strongly positive weights for class 1 and fire all clauses
        model.weights = jnp.stack(
            [jnp.full((cfg.n_clauses,), -50), jnp.full((cfg.n_clauses,), 50)]
        ).astype(jnp.int32)
        img = jnp.ones((4, 4), jnp.uint8)
        ta_d, w_d = sample_deltas(key, model, img, jnp.int32(1), cfg)
        # target update prob = (T - T)/2T = 0 -> no weight increment for y=1
        assert int(w_d[1].sum()) == 0


class TestTrainEvalEquivalence:
    """The matmul training eval must be bit-identical to the dense
    reference broadcast — same deltas under fixed keys, same models
    through batch and scan updates (the pre-refactor semantics contract)."""

    def _pair(self, **kw):
        cfg_m = _cfg(train_eval="matmul", **kw)
        return cfg_m, dataclasses.replace(cfg_m, train_eval="dense")

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_sample_deltas_identical(self, seed):
        cfg_m, cfg_d = self._pair()
        key = jax.random.PRNGKey(seed)
        model = init_model(key, cfg_m)
        # boundary states so a nontrivial include mask exists
        model.ta_state = jax.random.randint(
            key, model.ta_state.shape, TA_HALF - 6, TA_HALF + 6
        ).astype(jnp.uint8)
        img = (jax.random.uniform(key, (4, 4)) > 0.5).astype(jnp.uint8)
        for lbl in (0, 1):
            ta_m, w_m = sample_deltas(key, model, img, jnp.int32(lbl), cfg_m)
            ta_d, w_d = sample_deltas(key, model, img, jnp.int32(lbl), cfg_d)
            np.testing.assert_array_equal(np.asarray(ta_m), np.asarray(ta_d))
            np.testing.assert_array_equal(np.asarray(w_m), np.asarray(w_d))

    @pytest.mark.parametrize("mode", ["batch", "scan"])
    def test_update_batch_identical(self, mode):
        cfg_m, cfg_d = self._pair()
        key = jax.random.PRNGKey(17)
        model = init_model(key, cfg_m)
        imgs = (jax.random.uniform(key, (16, 4, 4)) > 0.5).astype(jnp.uint8)
        labels = jax.random.randint(key, (16,), 0, 2)
        m_m, m_d = model, model
        for _ in range(3):
            key, k = jax.random.split(key)
            m_m = update_batch(k, m_m, imgs, labels, cfg_m, mode=mode)
            m_d = update_batch(k, m_d, imgs, labels, cfg_d, mode=mode)
        np.testing.assert_array_equal(
            np.asarray(m_m.ta_state), np.asarray(m_d.ta_state)
        )
        np.testing.assert_array_equal(
            np.asarray(m_m.weights), np.asarray(m_d.weights)
        )

    def test_literal_budget_identical_across_paths(self):
        cfg_m, cfg_d = self._pair(max_included_literals=3, s=1.5)
        key = jax.random.PRNGKey(5)
        model = init_model(key, cfg_m)
        ta = np.full((cfg_m.n_clauses, cfg_m.n_literals), TA_HALF - 1, np.uint8)
        ta[:, :4] = TA_HALF
        model.ta_state = jnp.asarray(ta)
        img = (jax.random.uniform(key, (4, 4)) > 0.5).astype(jnp.uint8)
        ta_m, _ = sample_deltas(key, model, img, jnp.int32(1), cfg_m)
        ta_d, _ = sample_deltas(key, model, img, jnp.int32(1), cfg_d)
        np.testing.assert_array_equal(np.asarray(ta_m), np.asarray(ta_d))

    @pytest.mark.parametrize("mode", ["batch", "scan"])
    def test_update_batch_literals_matches_images(self, mode):
        """The literal-level public step equals the image-level one on the
        same batch (the precompute-once contract)."""
        from repro.core.train import batch_literals, update_batch_literals

        cfg = _cfg()
        key = jax.random.PRNGKey(23)
        model = init_model(key, cfg)
        imgs = (jax.random.uniform(key, (8, 4, 4)) > 0.5).astype(jnp.uint8)
        labels = jax.random.randint(key, (8,), 0, 2)
        lits = batch_literals(imgs, cfg)
        m_img = update_batch(key, model, imgs, labels, cfg, mode=mode)
        m_lit = update_batch_literals(key, model, lits, labels, cfg, mode=mode)
        np.testing.assert_array_equal(
            np.asarray(m_img.ta_state), np.asarray(m_lit.ta_state)
        )
        np.testing.assert_array_equal(
            np.asarray(m_img.weights), np.asarray(m_lit.weights)
        )

    def test_unknown_train_eval_rejected(self):
        cfg = _cfg(train_eval="bogus")
        key = jax.random.PRNGKey(0)
        model = init_model(key, cfg)
        img = (jax.random.uniform(key, (4, 4)) > 0.5).astype(jnp.uint8)
        with pytest.raises(ValueError, match="train_eval"):
            sample_deltas(key, model, img, jnp.int32(0), cfg)


class TestLearning:
    def test_noisy_xor_convolutional(self):
        tx, ty, vx, vy = noisy_xor_2d(n_train=1500, n_test=400, seed=0)
        tx, vx = booleanize_split(tx), booleanize_split(vx)
        # T=100 keeps the batch-mode updates from oscillating around the
        # threshold (T=20 bounced between 0.82 and 0.90 epoch to epoch).
        cfg = _cfg(n_clauses=40, T=100, s=5.0)
        key = jax.random.PRNGKey(42)
        model = init_model(key, cfg)
        txj, tyj = jnp.asarray(tx), jnp.asarray(ty.astype(np.int32))
        for _ in range(12):
            for i in range(0, 1500, 100):
                key, k = jax.random.split(key)
                model = update_batch(k, model, txj[i:i+100], tyj[i:i+100], cfg)
        acc = float(accuracy(model, jnp.asarray(vx), jnp.asarray(vy.astype(np.int32)), cfg))
        assert acc >= 0.85, f"noisy-XOR accuracy {acc}"

    @pytest.mark.slow
    def test_glyphs_paper_config_family(self):
        """10-class 28x28 task with the paper's exact geometry (128 clauses,
        10x10 window) — the MNIST stand-in integration test."""
        tx, ty, vx, vy = synthetic_glyphs(n_train=1500, n_test=300, seed=1)
        tx = booleanize_split(tx, method="threshold")
        vx = booleanize_split(vx, method="threshold")
        cfg = CoTMConfig(n_clauses=128, n_classes=10, T=100, s=5.0)
        key = jax.random.PRNGKey(0)
        model = init_model(key, cfg)
        txj, tyj = jnp.asarray(tx), jnp.asarray(ty.astype(np.int32))
        for _ in range(8):
            for i in range(0, 1500, 50):
                key, k = jax.random.split(key)
                model = update_batch(k, model, txj[i:i+50], tyj[i:i+50], cfg)
        acc = float(accuracy(model, jnp.asarray(vx), jnp.asarray(vy.astype(np.int32)), cfg))
        assert acc >= 0.8, f"glyph accuracy {acc}"
