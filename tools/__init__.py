# Repo maintenance tooling (linters, CI gates).  A package so tests can
# `import tools.tmlint` / `import tools.recompile_guard` from the repo root.
