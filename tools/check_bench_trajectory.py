#!/usr/bin/env python3
"""CI perf gate: fail on >15% throughput regression vs the committed
bench trajectory at tiny geometry.

Compares a freshly measured ``BENCH_serve.json`` (from ``benchmarks/run.py
--emit-json DIR --tiny``) against the last committed row of
``benchmarks/BENCH_trajectory.json``: the **median** best-cls/s drop
across the (path, bucket) keys both sides measured must stay within
``--threshold`` (default 15%).  The median is the gate signal because
single-key jitter at tiny geometry on shared CPU runners reaches
20-40% between identical runs, while a real code regression shifts many
keys at once (per-key drops are still printed).  This is what turns the
committed trajectory into a gate — a PR that slows a hot path has to
either fix it or consciously re-baseline the trajectory file
(ROADMAP item 5).

Exit codes: 0 pass / 1 regression / 0 with a notice when there is no
committed row yet or the fresh file is not tiny geometry.

Paper-geometry measurements are compared too, but **warn-only** (always
exit 0): paper runs are far slower and rarer in CI, so a noisy fail
would teach everyone to skip the gate — the tiny median stays the
blocking signal, and the paper drop lines appear in the log for a human
to read when touching the hot paths.

Escape hatches (documented in ARCHITECTURE.md §Autotune):
  * ``BENCH_GATE_SKIP=1``   — skip entirely (e.g. a known-slow runner);
  * ``BENCH_GATE_THRESHOLD``— override the regression threshold.

Usage:
    python tools/check_bench_trajectory.py --bench bench_out/BENCH_serve.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.trajectory import (  # noqa: E402
    TRAJECTORY_FILE,
    compare,
    distill_serve_rows,
    load_trajectory,
    median_drop,
    previous_row,
)


def _compare_geometry(payload: dict, trajectory_path: str,
                      geometry: str, threshold: float):
    """Compare a fresh payload against the committed row at *geometry*.

    Returns ``(results, med, prev)`` or ``None`` when there is nothing
    to compare (no committed row, no shared keys); prints the notice
    itself in that case.
    """
    prev = previous_row(load_trajectory(trajectory_path))
    if prev is None:
        print("bench gate: no committed trajectory row yet — nothing to "
              "compare (commit one with benchmarks/trajectory.py --update)")
        return None
    prev_best = (prev.get("geometries", {}).get(geometry, {})
                 .get("best_cls_per_s", {}))
    cur_best = distill_serve_rows(payload.get("rows", []))
    results = compare(prev_best, cur_best, threshold)
    if not results:
        print(f"bench gate: no shared (path, bucket) keys at {geometry!r} "
              "geometry between the fresh measurement and the committed "
              "row — skipping")
        return None
    return results, median_drop(results), prev


def _print_drops(results, med, prev, threshold: float) -> None:
    print(f"bench gate: vs committed row {prev.get('pr')!r} "
          f"({prev.get('generated_at')}), threshold {threshold:.0%} "
          "on the median drop across keys")
    for r in results:
        mark = "slow" if r["regressed"] else "ok"
        print(f"  {r['key']:24s} prev {r['prev_cls_per_s']:12,.0f}  "
              f"cur {r['cur_cls_per_s']:12,.0f}  "
              f"drop {r['drop']:+7.1%}  {mark}")
    print(f"  median drop across {len(results)} keys: {med:+.1%}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", required=True,
                    help="freshly measured BENCH_serve.json (tiny geometry)")
    ap.add_argument("--trajectory", default=TRAJECTORY_FILE)
    ap.add_argument("--threshold", type=float,
                    default=float(os.environ.get("BENCH_GATE_THRESHOLD", 0.15)))
    args = ap.parse_args()

    if os.environ.get("BENCH_GATE_SKIP"):
        print("bench gate: skipped (BENCH_GATE_SKIP set)")
        return 0

    with open(args.bench) as f:
        payload = json.load(f)
    geometry = payload.get("geometry")
    if geometry == "paper":
        # Warn-only: paper runs are too slow/rare in CI to block on, but
        # a regression at the geometry the paper reports is exactly what
        # a human wants to see in the log (module docstring).
        got = _compare_geometry(payload, args.trajectory, "paper",
                                args.threshold)
        if got is None:
            return 0
        results, med, prev = got
        _print_drops(results, med, prev, args.threshold)
        if med > args.threshold:
            print(f"bench gate: WARNING — paper-geometry median regression "
                  f"{med:.1%} exceeds {args.threshold:.0%} (warn-only, not "
                  "gated; the tiny median is the blocking signal — "
                  "investigate before re-baselining "
                  "benchmarks/BENCH_trajectory.json)")
        else:
            print(f"bench gate: paper geometry OK (median drop {med:+.1%}; "
                  "warn-only, never gated)")
        return 0
    if geometry != "tiny":
        print(f"bench gate: {args.bench} is {geometry!r} "
              "geometry, gate only runs at tiny — skipping")
        return 0

    got = _compare_geometry(payload, args.trajectory, "tiny", args.threshold)
    if got is None:
        return 0
    results, med, prev = got
    _print_drops(results, med, prev, args.threshold)
    if med > args.threshold:
        print(f"bench gate: FAIL — median regression {med:.1%} exceeds "
              f"{args.threshold:.0%} "
              "(set BENCH_GATE_SKIP=1 to bypass on a known-slow runner, or "
              "re-baseline benchmarks/BENCH_trajectory.json if intended)")
        return 1
    print(f"bench gate: PASS (median drop {med:+.1%} within threshold; "
          "per-key jitter on a shared runner is expected and not gated)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
