#!/usr/bin/env python3
"""CI perf gate: fail on >15% throughput regression vs the committed
bench trajectory at tiny geometry.

Compares a freshly measured ``BENCH_serve.json`` (from ``benchmarks/run.py
--emit-json DIR --tiny``) against the last committed row of
``benchmarks/BENCH_trajectory.json``: the **median** best-cls/s drop
across the (path, bucket) keys both sides measured must stay within
``--threshold`` (default 15%).  The median is the gate signal because
single-key jitter at tiny geometry on shared CPU runners reaches
20-40% between identical runs, while a real code regression shifts many
keys at once (per-key drops are still printed).  This is what turns the
committed trajectory into a gate — a PR that slows a hot path has to
either fix it or consciously re-baseline the trajectory file
(ROADMAP item 5).

Exit codes: 0 pass / 1 regression / 0 with a notice when there is no
committed row yet or the fresh file is not tiny geometry.

Escape hatches (documented in ARCHITECTURE.md §Autotune):
  * ``BENCH_GATE_SKIP=1``   — skip entirely (e.g. a known-slow runner);
  * ``BENCH_GATE_THRESHOLD``— override the regression threshold.

Usage:
    python tools/check_bench_trajectory.py --bench bench_out/BENCH_serve.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.trajectory import (  # noqa: E402
    TRAJECTORY_FILE,
    compare,
    distill_serve_rows,
    load_trajectory,
    median_drop,
    previous_row,
)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", required=True,
                    help="freshly measured BENCH_serve.json (tiny geometry)")
    ap.add_argument("--trajectory", default=TRAJECTORY_FILE)
    ap.add_argument("--threshold", type=float,
                    default=float(os.environ.get("BENCH_GATE_THRESHOLD", 0.15)))
    args = ap.parse_args()

    if os.environ.get("BENCH_GATE_SKIP"):
        print("bench gate: skipped (BENCH_GATE_SKIP set)")
        return 0

    with open(args.bench) as f:
        payload = json.load(f)
    if payload.get("geometry") != "tiny":
        print(f"bench gate: {args.bench} is {payload.get('geometry')!r} "
              "geometry, gate only runs at tiny — skipping")
        return 0

    prev = previous_row(load_trajectory(args.trajectory))
    if prev is None:
        print("bench gate: no committed trajectory row yet — nothing to "
              "compare (commit one with benchmarks/trajectory.py --update)")
        return 0
    prev_best = prev.get("geometries", {}).get("tiny", {}).get("best_cls_per_s", {})
    cur_best = distill_serve_rows(payload.get("rows", []))

    results = compare(prev_best, cur_best, args.threshold)
    if not results:
        print("bench gate: no shared (path, bucket) keys between the fresh "
              "measurement and the committed row — skipping")
        return 0

    med = median_drop(results)
    print(f"bench gate: vs committed row {prev.get('pr')!r} "
          f"({prev.get('generated_at')}), threshold {args.threshold:.0%} "
          "on the median drop across keys")
    for r in results:
        mark = "slow" if r["regressed"] else "ok"
        print(f"  {r['key']:24s} prev {r['prev_cls_per_s']:12,.0f}  "
              f"cur {r['cur_cls_per_s']:12,.0f}  "
              f"drop {r['drop']:+7.1%}  {mark}")
    print(f"  median drop across {len(results)} keys: {med:+.1%}")
    if med > args.threshold:
        print(f"bench gate: FAIL — median regression {med:.1%} exceeds "
              f"{args.threshold:.0%} "
              "(set BENCH_GATE_SKIP=1 to bypass on a known-slow runner, or "
              "re-baseline benchmarks/BENCH_trajectory.json if intended)")
        return 1
    print(f"bench gate: PASS (median drop {med:+.1%} within threshold; "
          "per-key jitter on a shared runner is expected and not gated)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
