#!/usr/bin/env python
"""Docs cross-reference check: no dangling markdown citations.

Scans every tracked ``.py``/``.md`` file for

  * repo-relative markdown references (``EXPERIMENTS.md``, or pathed
    like ``benchmarks/*.md`` — plain mentions or link targets), and
  * section-anchor citations of the form ``<file>.md §<Anchor>``
    (e.g. ``EXPERIMENTS.md §Perf/kernel``),

and fails when the cited file is not tracked or the cited anchor has no
matching heading (a heading line containing ``§<Anchor>``) in the target
file.  Eight docstrings cited ``EXPERIMENTS.md §Perf`` for months before
the file existed — this is the regression gate for that failure mode.

Conventions:
  * a bare name (``EXPERIMENTS.md``) resolves against the repo root and
    the citing file's own directory; a pathed reference resolves
    against the repo root, then the citing file's directory;
  * URLs (``...://...``) and glob-ish tokens are ignored;
  * ``ISSUE.md`` and ``CHANGES.md`` are skipped as *sources*: the task
    spec legitimately cites files that do not exist yet, the changelog
    files that no longer exist;
  * anchors match headings strictly: ``§Perf`` is satisfied by a heading
    containing ``§Perf`` but not by ``§Perf/kernel``.

Run:  python tools/check_docs_refs.py   (exit 1 on dangling references)
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# A markdown file token: word chars / dots / dashes, optional dir prefix.
MD_REF = re.compile(r"(?<![\w/.\-])((?:[\w.\-]+/)*[\w.\-]+\.md)\b")
# "<file>.md §Anchor" (whitespace may include a line break inside a
# wrapped docstring).  Anchors are /-separated identifiers.
ANCHOR_REF = re.compile(r"([\w.\-/]+\.md)\s*§([A-Za-z0-9_]+(?:/[A-Za-z0-9_]+)*)")

SKIP_SOURCES = {"ISSUE.md", "CHANGES.md"}


def tracked_files() -> list[str]:
    try:
        # --others --exclude-standard also picks up files created but not
        # yet committed, so the check is usable mid-development too.
        out = subprocess.run(
            ["git", "ls-files", "--cached", "--others", "--exclude-standard",
             "*.py", "*.md"],
            cwd=REPO, capture_output=True, text=True, check=True,
        ).stdout
        files = [ln for ln in out.splitlines() if ln]
        if files:
            return files
    except (OSError, subprocess.CalledProcessError):
        pass
    # Fallback outside git: walk the repo.
    files = []
    for root, dirs, names in os.walk(REPO):
        dirs[:] = [d for d in dirs if not d.startswith(".") and d != "__pycache__"]
        for n in names:
            if n.endswith((".py", ".md")):
                files.append(os.path.relpath(os.path.join(root, n), REPO))
    return files


def resolve(ref: str, src: str, tracked: set[str]) -> str | None:
    """The tracked path a citation refers to, or None if dangling."""
    candidates = [ref, os.path.normpath(os.path.join(os.path.dirname(src), ref))]
    for c in candidates:
        if c in tracked:
            return c
    return None


def heading_has_anchor(target_text: str, anchor: str) -> bool:
    pat = re.compile(
        r"^#{1,6}\s.*§" + re.escape(anchor) + r"(?![\w/])", re.MULTILINE
    )
    return bool(pat.search(target_text))


def main() -> int:
    files = tracked_files()
    tracked = set(files)
    texts = {}
    for f in files:
        try:
            with open(os.path.join(REPO, f), encoding="utf-8") as fh:
                texts[f] = fh.read()
        except OSError:
            texts[f] = ""

    errors = []
    for src in files:
        if os.path.basename(src) in SKIP_SOURCES:
            continue
        text = texts[src]
        # URLs need no special-casing: every path segment inside one is
        # preceded by '/' or ':', which MD_REF's lookbehind rejects, so
        # only repo-local citations ever match.
        cited_files = set(MD_REF.findall(text))
        for ref in sorted(cited_files):
            if resolve(ref, src, tracked) is None:
                errors.append(f"{src}: cites {ref!r} — no such tracked file")
        for ref, anchor in set(ANCHOR_REF.findall(text)):
            target = resolve(ref, src, tracked)
            if target is None:
                continue  # already reported above
            if not heading_has_anchor(texts[target], anchor):
                errors.append(
                    f"{src}: cites {ref} §{anchor} — no heading with "
                    f"§{anchor} in {target}"
                )

    if errors:
        print(f"{len(errors)} dangling docs reference(s):", file=sys.stderr)
        for e in sorted(errors):
            print(f"  {e}", file=sys.stderr)
        return 1
    print(f"docs cross-references OK ({len(files)} files scanned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
