"""Reusable no-recompile-after-warmup guard for jitted callables.

Generalizes the check PR 6 hard-coded in
``tests/test_autotune.py::test_no_recompile_after_warmup``: snapshot the
jit cache sizes of the executables under test, run traffic, and fail if
any cache grew — i.e. if serving/training work compiled something warmup
did not cover.

Works on anything exposing jax's ``_cache_size()`` (the callables
returned by ``jax.jit`` / ``functools.partial(jax.jit, ...)``).  Targets
may be passed directly or as ``(holder, "attr")`` pairs, which are
re-resolved at enter *and* exit so lazily-built / rebound jit wrappers
(e.g. ``repro.serve.engine._raw_step_jit``) are tracked through the
rebinding.  An attribute that is ``None`` at enter counts as size 0, so
a jit wrapper first *built* inside the guarded region is correctly
reported as a recompile.

Usage::

    from tools.recompile_guard import RecompileGuard, no_recompiles

    with no_recompiles(engine_mod.classify_step,
                       (engine_mod, "_raw_step_jit")):
        eng.classify(...)          # traffic that must not compile

    guard = RecompileGuard(my_jitted, allow=1)   # tolerate one build
    with guard: ...
    guard.deltas                                  # post-exit accounting
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple, Union

__all__ = ["CacheDelta", "RecompileError", "RecompileGuard", "no_recompiles"]

Target = Union[Any, Tuple[Any, str]]


@dataclasses.dataclass(frozen=True)
class CacheDelta:
    """Jit cache growth of one target across the guarded region."""

    name: str
    before: int
    after: int

    @property
    def grew(self) -> int:
        return self.after - self.before


class RecompileError(AssertionError):
    """A guarded region compiled more than it was allowed to."""

    def __init__(
        self, deltas: Sequence[CacheDelta], allow: int, expect=None
    ):
        self.deltas = list(deltas)
        grew = [d for d in deltas if d.grew > 0]
        detail = ", ".join(f"{d.name}: {d.before}->{d.after}" for d in grew)
        total = sum(d.grew for d in grew)
        if expect is not None:
            msg = (
                f"jit cache grew by {total} (expected exactly {expect}) "
                f"inside a recompile-delta region: {detail or 'no growth'}. "
                f"The region compiled a different delta than asserted."
            )
        else:
            msg = (
                f"jit cache grew by {total} "
                f"(allowed {allow}) inside a no-recompile region: {detail}. "
                f"Warmup does not cover everything this traffic dispatches."
            )
        super().__init__(msg)


def _resolve(targets: Sequence[Target]) -> List[Tuple[str, Any]]:
    out: List[Tuple[str, Any]] = []
    for t in targets:
        if isinstance(t, tuple) and len(t) == 2 and isinstance(t[1], str):
            holder, attr = t
            holder_name = getattr(holder, "__name__", type(holder).__name__)
            out.append((f"{holder_name}.{attr}", getattr(holder, attr, None)))
        else:
            out.append((getattr(t, "__name__", repr(t)), t))
    return out


def _cache_size(fn: Any) -> int:
    if fn is None:
        return 0
    size = getattr(fn, "_cache_size", None)
    if size is None:
        raise TypeError(
            f"{fn!r} has no _cache_size(); pass the callable returned by "
            f"jax.jit (or a (holder, attr) pair resolving to one)"
        )
    return int(size())


class RecompileGuard:
    """Context manager asserting the targets' jit caches do not grow.

    Args:
      *targets: jitted callables, or ``(holder, "attr")`` pairs resolved
        lazily at enter and exit.
      allow: total cache growth tolerated across all targets (default 0).
      expect: assert the region compiles EXACTLY this many entries
        (overrides ``allow``) — the swap-compiles-only-the-delta
        assertion: ``expect=0`` proves a hot swap reused every warm
        executable, ``expect=N`` proves a first-time shape compiled
        exactly its N expected steps and nothing else.
    """

    def __init__(
        self, *targets: Target, allow: int = 0, expect: int | None = None
    ):
        if not targets:
            raise ValueError("RecompileGuard needs at least one target")
        if expect is not None and expect < 0:
            raise ValueError("expect must be >= 0")
        self._targets = targets
        self.allow = allow
        self.expect = expect
        self.deltas: List[CacheDelta] = []
        self._before: Dict[str, int] = {}

    def __enter__(self) -> "RecompileGuard":
        self._before = {
            name: _cache_size(fn) for name, fn in _resolve(self._targets)
        }
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.deltas = [
            CacheDelta(name, self._before.get(name, 0), _cache_size(fn))
            for name, fn in _resolve(self._targets)
        ]
        if exc_type is not None:
            return  # don't mask the in-flight exception
        grew = sum(d.grew for d in self.deltas if d.grew > 0)
        if self.expect is not None:
            if grew != self.expect:
                raise RecompileError(self.deltas, self.allow, self.expect)
        elif grew > self.allow:
            raise RecompileError(self.deltas, self.allow)


def no_recompiles(
    *targets: Target, allow: int = 0, expect: int | None = None
) -> RecompileGuard:
    """``with no_recompiles(fn, (mod, "attr")): ...`` — zero-growth guard
    (``expect=N`` asserts exactly-N growth instead)."""
    return RecompileGuard(*targets, allow=allow, expect=expect)
