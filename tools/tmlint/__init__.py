"""tmlint: repo-aware static analysis for jit / Pallas / concurrency contracts.

This repo layers three kinds of invariants on top of ordinary Python
correctness, none of which a generic linter knows about:

* **jit boundaries** — ``static_argnames`` must name hashable arguments
  (frozen dataclasses), donated buffers must not be read after the
  jitted call, and hot-path modules must not silently sync the host.
* **Pallas kernel contracts** — every ``pl.pallas_call`` entry point
  must be interpretable on CPU (``interpret=`` plumbed through), must
  have a bit-exact oracle registered in ``kernels/ref.py`` via the
  per-module ``PALLAS_ORACLES`` annotation that
  ``repro.kernels.registry`` aggregates, and must derive its grid from
  the shared pad-to-multiple helpers in ``kernels/shapes.py`` instead
  of raw ``//`` / ``%`` arithmetic.
* **asyncio / thread discipline** — no blocking calls on the serving
  event loop, and ``MicrobatchScheduler`` state is only touched through
  its methods.

tmlint encodes those contracts as AST checks over ``src/repro``.  It is
**stdlib-only** (no jax import) so it runs anywhere, including minimal
CI containers.  Accepted pre-existing findings live in ``baseline.json``
with per-entry justifications; everything else fails the run.

Usage::

    python -m tools.tmlint src/repro            # lint (exit 1 on findings)
    python -m tools.tmlint --no-baseline ...    # show baselined findings too
    python -m tools.tmlint --dead-modules       # dead-module report (REPORT.md)

See ``ARCHITECTURE.md`` §Static analysis and ``tests/test_tmlint.py``
(each rule pinned with positive/negative fixtures).
"""

from tools.tmlint.core import (  # noqa: F401
    Baseline,
    Finding,
    LintResult,
    run_lint,
)
from tools.tmlint.rules import RULE_DOCS  # noqa: F401
