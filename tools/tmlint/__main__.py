"""CLI: ``python -m tools.tmlint [paths...]``.

Exit codes: 0 clean (after baseline), 1 findings, 2 bad invocation.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from tools.tmlint.core import Baseline, run_lint
from tools.tmlint.deadmod import dead_modules, render_report
from tools.tmlint.rules import RULE_DOCS

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="tools.tmlint",
        description="Repo-aware static analysis for jit/Pallas/concurrency contracts.",
    )
    parser.add_argument(
        "paths", nargs="*", default=["src/repro"], help="files or directories to lint"
    )
    parser.add_argument(
        "--baseline",
        type=Path,
        default=DEFAULT_BASELINE,
        help="baseline JSON of accepted findings (default: tools/tmlint/baseline.json)",
    )
    parser.add_argument(
        "--no-baseline",
        action="store_true",
        help="ignore the baseline and report every finding",
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="print rule IDs and exit"
    )
    parser.add_argument(
        "--prune-baseline",
        action="store_true",
        help="after linting, rewrite the baseline file with stale entries "
        "(suppressions matching no current finding) removed",
    )
    parser.add_argument(
        "--dead-modules",
        action="store_true",
        help="print the dead-module report (markdown) instead of linting",
    )
    parser.add_argument(
        "--src-root",
        type=Path,
        default=Path("src"),
        help="source root for --dead-modules (default: src)",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule_id, doc in sorted(RULE_DOCS.items()):
            print(f"{rule_id}  {doc}")
        return 0

    if args.dead_modules:
        if not (args.src_root / "repro").is_dir():
            print(f"error: {args.src_root}/repro not found", file=sys.stderr)
            return 2
        result = dead_modules(args.src_root, Path("tests"), Path("benchmarks"))
        print(render_report(result), end="")
        return 0

    paths = [Path(p) for p in args.paths]
    for p in paths:
        if not p.exists():
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2

    if args.no_baseline:
        baseline = Baseline.empty()
    elif args.baseline.exists():
        baseline = Baseline.load(args.baseline)
    else:
        baseline = Baseline.empty()

    result = run_lint(paths, root=Path.cwd(), baseline=baseline)

    for f in result.findings:
        print(f.render())
    if result.suppressed:
        print(
            f"tmlint: {len(result.suppressed)} finding(s) suppressed by "
            f"{args.baseline}" + (" (ignored)" if args.no_baseline else ""),
            file=sys.stderr,
        )
    for e in result.stale_baseline:
        print(
            f"tmlint: stale baseline entry (matched nothing): "
            f"{e['rule']} {e['path']} [{e['scope']}]",
            file=sys.stderr,
        )
    if args.prune_baseline and not args.no_baseline and args.baseline.exists():
        if result.stale_baseline:
            import json

            data = json.loads(args.baseline.read_text(encoding="utf-8"))
            data["suppressions"] = baseline.live_entries()
            args.baseline.write_text(
                json.dumps(data, indent=2, ensure_ascii=False) + "\n",
                encoding="utf-8",
            )
            print(
                f"tmlint: pruned {len(result.stale_baseline)} stale "
                f"entr{'y' if len(result.stale_baseline) == 1 else 'ies'} "
                f"from {args.baseline}",
                file=sys.stderr,
            )
        else:
            print(
                f"tmlint: no stale entries in {args.baseline}; "
                f"nothing to prune",
                file=sys.stderr,
            )
    status = "clean" if result.ok else f"{len(result.findings)} finding(s)"
    print(
        f"tmlint: {result.files_scanned} file(s) scanned, {status}",
        file=sys.stderr,
    )
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
