"""tmlint core: file walking, module contexts, repo index, baseline, runner.

The lint is two-pass:

1. **Index pass** — parse every file once and build a :class:`RepoIndex`
   with the cross-file facts rules need (which classes are frozen
   dataclasses, which functions exist in ``kernels/ref.py``).
2. **Rule pass** — run every rule over every module context.

Baseline fingerprints are *line-number free* — ``(rule, path, scope,
stripped line text)`` — so unrelated edits above a finding don't rot the
baseline.  Every baseline entry must carry a non-empty justification.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import os
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "Finding",
    "ModuleCtx",
    "RepoIndex",
    "Baseline",
    "LintResult",
    "iter_py_files",
    "build_index",
    "run_lint",
    "HOT_PATH_SUFFIXES",
]

#: Modules on the serving/training hot path: host syncs here stall the
#: dispatch pipeline, so TM103 applies (matched by posix path suffix).
HOT_PATH_SUFFIXES: Tuple[str, ...] = (
    "serve/engine.py",
    "serve/paths.py",
    "serve/mesh.py",
    "train/tm_engine.py",
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint finding; ``fingerprint()`` is the baseline identity."""

    rule: str
    path: str       # posix relpath from the lint root
    line: int       # 1-based, for display only (not part of the fingerprint)
    scope: str      # enclosing qualname, or "<module>"
    message: str
    line_text: str  # stripped source line, the stable part of the identity

    def fingerprint(self) -> Tuple[str, str, str, str]:
        return (self.rule, self.path, self.scope, self.line_text)

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} [{self.scope}] {self.message}"


@dataclasses.dataclass
class ModuleCtx:
    """Everything a rule needs about one parsed module."""

    path: Path          # absolute
    relpath: str        # posix, relative to the lint root
    tree: ast.Module
    lines: List[str]    # source lines (for line_text)
    is_hot: bool        # matches HOT_PATH_SUFFIXES
    parents: Dict[int, ast.AST]  # id(node) -> parent node

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: str, node: ast.AST, scope: str, message: str) -> Finding:
        lineno = getattr(node, "lineno", 1)
        return Finding(
            rule=rule,
            path=self.relpath,
            line=lineno,
            scope=scope,
            message=message,
            line_text=self.line_text(lineno),
        )


@dataclasses.dataclass
class DataclassInfo:
    name: str
    frozen: bool
    eq: bool
    has_hash: bool

    @property
    def hashable(self) -> bool:
        # dataclass(eq=True, frozen=False) sets __hash__ = None unless the
        # class defines its own; eq=False inherits object.__hash__.
        return self.frozen or self.has_hash or not self.eq


@dataclasses.dataclass
class RepoIndex:
    """Cross-file facts shared by all rules."""

    #: class name -> info, for every @dataclass in the scanned tree.  Keyed
    #: by bare name: annotations rarely carry the full module path, and a
    #: name collision at worst makes TM101 conservative.
    dataclass_index: Dict[str, DataclassInfo] = dataclasses.field(default_factory=dict)
    #: top-level function names defined in kernels/ref.py (oracle targets).
    ref_functions: Set[str] = dataclasses.field(default_factory=set)
    #: whether a kernels/ref.py was part of the scanned tree at all.
    has_ref_module: bool = False


def iter_py_files(paths: Sequence[Path]) -> Iterable[Path]:
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if "__pycache__" in f.parts:
                    continue
                yield f


def _attach_parents(tree: ast.Module) -> Dict[int, ast.AST]:
    parents: Dict[int, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[id(child)] = node
    return parents


def load_module(path: Path, root: Path, hot_suffixes: Sequence[str]) -> ModuleCtx:
    source = path.read_text(encoding="utf-8")
    tree = ast.parse(source, filename=str(path))
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    return ModuleCtx(
        path=path,
        relpath=rel,
        tree=tree,
        lines=source.splitlines(),
        is_hot=any(rel.endswith(s) for s in hot_suffixes),
        parents=_attach_parents(tree),
    )


def _dataclass_info(node: ast.ClassDef) -> Optional[DataclassInfo]:
    """DataclassInfo if ``node`` carries a @dataclass decorator, else None."""
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = dotted_name(target)
        if name not in ("dataclass", "dataclasses.dataclass"):
            continue
        frozen = eq = None
        if isinstance(dec, ast.Call):
            for kw in dec.keywords:
                if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
                    frozen = bool(kw.value.value)
                if kw.arg == "eq" and isinstance(kw.value, ast.Constant):
                    eq = bool(kw.value.value)
        has_hash = any(
            isinstance(b, ast.FunctionDef) and b.name == "__hash__" for b in node.body
        )
        return DataclassInfo(
            name=node.name,
            frozen=bool(frozen),
            eq=True if eq is None else eq,
            has_hash=has_hash,
        )
    return None


def build_index(modules: Sequence[ModuleCtx]) -> RepoIndex:
    index = RepoIndex()
    for ctx in modules:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                info = _dataclass_info(node)
                if info is not None:
                    prev = index.dataclass_index.get(info.name)
                    # On collision keep the *unhashable* variant: rules
                    # stay conservative rather than silently passing.
                    if prev is None or prev.hashable:
                        index.dataclass_index[info.name] = info
        if ctx.relpath.endswith("kernels/ref.py"):
            index.has_ref_module = True
            for node in ctx.tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    index.ref_functions.add(node.name)
    return index


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class Baseline:
    """Committed suppressions for accepted pre-existing findings.

    JSON shape::

        {"version": 1,
         "suppressions": [
            {"rule": "TM103", "path": "src/repro/serve/engine.py",
             "scope": "InFlightClassify.result",
             "line_text": "jax.block_until_ready(raw)",
             "justification": "result() IS the intentional sync point"},
            ...]}

    Every entry MUST have a non-empty justification — a baseline entry is
    a reviewed decision, not a mute button.
    """

    def __init__(self, entries: Sequence[dict]):
        self._entries = list(entries)
        self._index: Dict[Tuple[str, str, str, str], dict] = {}
        for i, e in enumerate(entries):
            missing = {"rule", "path", "scope", "line_text"} - set(e)
            if missing:
                raise ValueError(f"baseline entry {i} missing keys: {sorted(missing)}")
            if not str(e.get("justification", "")).strip():
                raise ValueError(
                    f"baseline entry {i} ({e['rule']} {e['path']}) has no "
                    f"justification; every suppression must say why"
                )
            key = (e["rule"], e["path"], e["scope"], e["line_text"])
            self._index[key] = e
        self._hits: Set[Tuple[str, str, str, str]] = set()

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        data = json.loads(path.read_text(encoding="utf-8"))
        if data.get("version") != 1:
            raise ValueError(f"unsupported baseline version: {data.get('version')!r}")
        return cls(data.get("suppressions", []))

    @classmethod
    def empty(cls) -> "Baseline":
        return cls([])

    def suppresses(self, finding: Finding) -> bool:
        key = finding.fingerprint()
        if key in self._index:
            self._hits.add(key)
            return True
        return False

    def stale_entries(self) -> List[dict]:
        """Entries that matched no finding — candidates for removal."""
        return [
            e
            for key, e in self._index.items()
            if key not in self._hits
        ]

    def live_entries(self) -> List[dict]:
        """Entries that matched a finding in the last run — what
        ``--prune-baseline`` keeps, in original file order."""
        return [
            e
            for e in self._entries
            if (e["rule"], e["path"], e["scope"], e["line_text"])
            in self._hits
        ]


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]        # unsuppressed (these fail the run)
    suppressed: List[Finding]      # matched a baseline entry
    stale_baseline: List[dict]     # baseline entries that matched nothing
    files_scanned: int

    @property
    def ok(self) -> bool:
        return not self.findings


def run_lint(
    paths: Sequence[Path],
    *,
    root: Optional[Path] = None,
    baseline: Optional[Baseline] = None,
    hot_suffixes: Sequence[str] = HOT_PATH_SUFFIXES,
    rules: Optional[Sequence] = None,
) -> LintResult:
    """Lint ``paths`` (files or directories) and apply the baseline."""
    from tools.tmlint.rules import ALL_RULES

    root = (root or Path.cwd()).resolve()
    baseline = baseline or Baseline.empty()
    active = list(rules) if rules is not None else list(ALL_RULES)

    modules = [
        load_module(f, root, hot_suffixes)
        for f in iter_py_files([Path(p) for p in paths])
    ]
    index = build_index(modules)

    findings: List[Finding] = []
    suppressed: List[Finding] = []
    for ctx in modules:
        for rule in active:
            for f in rule(ctx, index):
                (suppressed if baseline.suppresses(f) else findings).append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    suppressed.sort(key=lambda f: (f.path, f.line, f.rule))
    return LintResult(
        findings=findings,
        suppressed=suppressed,
        stale_baseline=baseline.stale_entries(),
        files_scanned=len(modules),
    )
