"""Dead-module report: repro modules unreachable from the entry surfaces.

Builds the ``repro.*`` import graph by parsing every file under the
source tree, then BFS-es from the roots the repo actually runs:

* every module under ``repro.launch``, ``repro.serve`` and
  ``repro.train`` (the CLI / serving / training entry surfaces), and
* every ``repro.*`` module imported by ``tests/``.

Anything not reached is reported as dead.  Modules that *are* imported
by ``benchmarks/`` are annotated rather than excused — a module only a
benchmark uses is still invisible to the product surfaces.  The report
is informational: nothing is deleted (see ``tools/tmlint/REPORT.md``,
regenerated with ``python -m tools.tmlint --dead-modules``).
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Sequence, Set

from tools.tmlint.core import iter_py_files

__all__ = ["build_import_graph", "dead_modules", "render_report"]

ROOT_PREFIXES = ("repro.launch", "repro.serve", "repro.train")


def _module_name(py: Path, src_root: Path) -> str:
    rel = py.relative_to(src_root).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _imports_of(py: Path, known: Set[str]) -> Set[str]:
    """repro.* modules imported by ``py`` (resolved against ``known``)."""
    try:
        tree = ast.parse(py.read_text(encoding="utf-8"))
    except SyntaxError:
        return set()
    out: Set[str] = set()

    def note(mod: str) -> None:
        # `from repro.kernels import ops` can mean module repro.kernels.ops
        # or attribute of repro.kernels; prefer the module if it exists.
        if mod in known:
            out.add(mod)
        else:
            # credit the longest known package prefix (its __init__ runs)
            while "." in mod:
                mod = mod.rsplit(".", 1)[0]
                if mod in known:
                    out.add(mod)
                    break

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "repro" or a.name.startswith("repro."):
                    note(a.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:  # relative import: resolve against this file
                base = _relative_base(py, node.level)
                if base is None:
                    continue
                mod = f"{base}.{node.module}" if node.module else base
            else:
                mod = node.module or ""
            if not (mod == "repro" or mod.startswith("repro.")):
                continue
            note(mod)
            for a in node.names:
                note(f"{mod}.{a.name}")
    return out


def _relative_base(py: Path, level: int) -> str:
    """Package name ``level`` steps up from ``py`` (None-safe best effort)."""
    parts = list(py.parts)
    try:
        i = parts.index("repro")
    except ValueError:
        return None
    pkg = parts[i:-1] if py.name != "__init__.py" else parts[i:-1]
    # one level = current package; each extra level pops one
    pkg = pkg[: len(pkg) - (level - 1)] if level > 1 else pkg
    return ".".join(pkg) if pkg else None


def build_import_graph(src_root: Path) -> Dict[str, Set[str]]:
    """module -> set of repro modules it imports (incl. implied packages)."""
    files = {f: None for f in iter_py_files([src_root / "repro"])}
    names = {_module_name(f, src_root): f for f in files}
    known = set(names)
    graph: Dict[str, Set[str]] = {}
    for mod, f in names.items():
        deps = _imports_of(f, known)
        # importing repro.a.b implies running repro and repro.a __init__s
        for d in list(deps):
            while "." in d:
                d = d.rsplit(".", 1)[0]
                if d in known:
                    deps.add(d)
        # a package reaches nothing implicitly, but a module implies its
        # own ancestor packages were imported first
        anc = mod
        while "." in anc:
            anc = anc.rsplit(".", 1)[0]
            if anc in known:
                deps.add(anc)
        graph[mod] = deps - {mod}
    return graph


def _external_roots(graph: Dict[str, Set[str]], scan_dirs: Sequence[Path]) -> Set[str]:
    known = set(graph)
    roots: Set[str] = set()
    for d in scan_dirs:
        if not d.exists():
            continue
        for f in iter_py_files([d]):
            roots |= _imports_of(f, known)
    return roots


def dead_modules(
    src_root: Path, tests_dir: Path, benchmarks_dir: Path
) -> Dict[str, List[str]]:
    """{"dead": [...], "bench_only": [...]} module lists (sorted)."""
    graph = build_import_graph(src_root)
    roots = {m for m in graph if m.startswith(ROOT_PREFIXES) or m == "repro"}
    roots |= _external_roots(graph, [tests_dir])
    roots &= set(graph)

    reached: Set[str] = set()
    frontier = list(roots)
    while frontier:
        m = frontier.pop()
        if m in reached:
            continue
        reached.add(m)
        frontier.extend(graph.get(m, ()))

    dead = sorted(set(graph) - reached)
    bench_roots = _external_roots(graph, [benchmarks_dir])
    bench_reached: Set[str] = set()
    frontier = [m for m in bench_roots if m in graph]
    while frontier:
        m = frontier.pop()
        if m in bench_reached:
            continue
        bench_reached.add(m)
        frontier.extend(graph.get(m, ()))
    return {
        "dead": [m for m in dead if m not in bench_reached],
        "bench_only": [m for m in dead if m in bench_reached],
    }


def render_report(result: Dict[str, List[str]]) -> str:
    lines = [
        "# tmlint dead-module report",
        "",
        "Modules under `src/repro` imported by nothing reachable from the",
        "entry surfaces (`repro.launch`, `repro.serve`, `repro.train`) or",
        "`tests/`.  Informational only — nothing is deleted.  Regenerate",
        "with `python -m tools.tmlint --dead-modules > tools/tmlint/REPORT.md`.",
        "",
        "## Dead (unreachable from entry surfaces, tests and benchmarks)",
        "",
    ]
    if result["dead"]:
        lines += [f"- `{m}`" for m in result["dead"]]
    else:
        lines.append("*(none)*")
    lines += [
        "",
        "## Reachable only from `benchmarks/`",
        "",
        "Not dead, but invisible to the product surfaces — candidates to",
        "fold into the serving/training paths or retire with the bench.",
        "",
    ]
    if result["bench_only"]:
        lines += [f"- `{m}`" for m in result["bench_only"]]
    else:
        lines.append("*(none)*")
    lines.append("")
    return "\n".join(lines)
