"""tmlint rules: TM1xx jit boundaries, TM2xx Pallas contracts, TM3xx concurrency.

Every rule is a callable ``rule(ctx: ModuleCtx, index: RepoIndex) ->
Iterable[Finding]`` registered in :data:`ALL_RULES`.  Rules are
deliberately *repo-aware*: they encode this codebase's conventions
(``PALLAS_ORACLES`` registries, ``kernels/shapes.py`` grid helpers,
``MicrobatchScheduler`` encapsulation) rather than generic Python style.

Positive and negative fixtures for each rule live in
``tests/test_tmlint.py``; keep them in sync when changing a rule.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, Iterator, List, Optional, Set, Tuple

from tools.tmlint.core import Finding, ModuleCtx, RepoIndex, dotted_name

__all__ = ["ALL_RULES", "RULE_DOCS"]

RULE_DOCS: Dict[str, str] = {
    "TM101": "jit static_argnames must name hashable (frozen-dataclass) arguments",
    "TM102": "buffer donated to a jitted call is read again afterwards",
    "TM103": "host-sync call (.item/np.asarray/block_until_ready/int-in-loop) in a hot-path module",
    "TM201": "pl.pallas_call must plumb interpret= so oracles can run on CPU",
    "TM202": "pallas entry point missing from the module's PALLAS_ORACLES registry (or oracle absent from kernels/ref.py)",
    "TM203": "raw // or % in a pallas wrapper; use kernels/shapes.py grid helpers",
    "TM301": "blocking call inside async def (event-loop stall)",
    "TM302": "MicrobatchScheduler internal state touched from outside its methods",
    "TM303": "ServingEngine._servables mutated outside register/swap/rollback (hot-swap atomicity bypass)",
    "TM304": "broad except in serve/ that swallows the failure without re-raising, resolving a future, or recording to a stats/health sink",
}

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)
_SCOPE_NODES = _FUNC_NODES + (ast.ClassDef,)
_NESTED_SCOPES = _SCOPE_NODES + (ast.Lambda,)


def scope_of(ctx: ModuleCtx, node: ast.AST) -> str:
    """Qualname of the scope enclosing ``node`` (e.g. ``Engine.stop``)."""
    parts: List[str] = []
    cur = ctx.parents.get(id(node))
    while cur is not None:
        if isinstance(cur, _SCOPE_NODES):
            parts.append(cur.name)
        cur = ctx.parents.get(id(cur))
    return ".".join(reversed(parts)) or "<module>"


def walk_local(root: ast.AST) -> Iterator[ast.AST]:
    """Walk ``root`` without descending into nested function/class scopes."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(root))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, _NESTED_SCOPES):
            stack.extend(ast.iter_child_nodes(node))


def _iter_functions(tree: ast.Module) -> Iterator[ast.AST]:
    for node in ast.walk(tree):
        if isinstance(node, _FUNC_NODES):
            yield node


def _is_pallas_call(call: ast.Call) -> bool:
    name = dotted_name(call.func)
    return name is not None and (
        name == "pallas_call" or name.endswith(".pallas_call")
    )


# --------------------------------------------------------------------------
# TM101: jit static_argnames must be hashable
# --------------------------------------------------------------------------

_JIT_NAMES = {"jax.jit", "jit"}
_PARTIAL_NAMES = {"functools.partial", "partial"}


def _string_list(node: Optional[ast.AST]) -> List[str]:
    """static_argnames value -> list of names (str constant or tuple/list)."""
    if node is None:
        return []
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [
            e.value
            for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, str)
        ]
    return []


def _annotation_type_names(node: Optional[ast.AST]) -> Set[str]:
    """Base type names mentioned by an annotation (Optional[X] -> {X}, ...)."""
    out: Set[str] = set()
    if node is None:
        return out
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        try:
            node = ast.parse(node.value, mode="eval").body
        except SyntaxError:
            return out
    if isinstance(node, ast.Name):
        out.add(node.id)
    elif isinstance(node, ast.Attribute):
        out.add(node.attr)
    elif isinstance(node, ast.Subscript):
        out |= _annotation_type_names(node.slice)
        # Optional/Tuple/... containers themselves are typing constructs;
        # only the contained names matter for hashability of the value.
    elif isinstance(node, ast.Tuple):
        for e in node.elts:
            out |= _annotation_type_names(e)
    elif isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):
        out |= _annotation_type_names(node.left)
        out |= _annotation_type_names(node.right)
    return out


def _func_params(fn: ast.AST) -> Dict[str, Optional[ast.AST]]:
    args = fn.args
    params: Dict[str, Optional[ast.AST]] = {}
    for a in list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs):
        params[a.arg] = a.annotation
    return params


def _jit_sites(ctx: ModuleCtx) -> Iterator[Tuple[ast.Call, Optional[ast.AST]]]:
    """Yield (jit call, wrapped FunctionDef or None) for every jit wrap."""
    defs_by_name: Dict[str, ast.AST] = {
        fn.name: fn for fn in _iter_functions(ctx.tree)
    }
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        if name in _JIT_NAMES:
            wrapped = None
            if node.args and isinstance(node.args[0], ast.Name):
                wrapped = defs_by_name.get(node.args[0].id)
            yield node, wrapped
        elif name in _PARTIAL_NAMES and node.args:
            if dotted_name(node.args[0]) in _JIT_NAMES:
                # functools.partial(jax.jit, ...) as a decorator: the
                # wrapped function is the decorated def.
                parent = ctx.parents.get(id(node))
                wrapped = None
                if isinstance(parent, _FUNC_NODES) and node in parent.decorator_list:
                    wrapped = parent
                yield node, wrapped


def rule_tm101_static_hashable(
    ctx: ModuleCtx, index: RepoIndex
) -> Iterable[Finding]:
    for call, wrapped in _jit_sites(ctx):
        static = []
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                static = _string_list(kw.value)
        if not static or wrapped is None:
            continue
        params = _func_params(wrapped)
        for arg_name in static:
            for type_name in _annotation_type_names(params.get(arg_name)):
                info = index.dataclass_index.get(type_name)
                if info is not None and not info.hashable:
                    yield ctx.finding(
                        "TM101",
                        call,
                        scope_of(ctx, call),
                        f"static_argnames includes {arg_name!r} annotated "
                        f"{type_name}, a non-frozen dataclass without "
                        f"__hash__ — jit will raise at trace time; freeze "
                        f"the dataclass or define __hash__",
                    )


# --------------------------------------------------------------------------
# TM102: donated buffers must not be read after the jitted call
# --------------------------------------------------------------------------


def _donate_positions(node: Optional[ast.AST]) -> Tuple[int, ...]:
    """donate_argnums value -> positions (IfExp takes both branches)."""
    if node is None:
        return ()
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(
            e.value
            for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, int)
        )
    if isinstance(node, ast.IfExp):
        return tuple(
            sorted(set(_donate_positions(node.body) + _donate_positions(node.orelse)))
        )
    return ()


def _donating_callables(ctx: ModuleCtx) -> Dict[Tuple[str, str], Tuple[int, ...]]:
    """Map ("name", f) / ("self", attr) -> donated positions.

    Covers the repo's three idioms::

        f = jax.jit(g, donate_argnums=(0,))          # ("name", "f")
        @functools.partial(jax.jit, donate_argnums=(0,))
        def f(...): ...                               # ("name", "f")
        def _build_x(self): return jax.jit(..., donate_argnums=(0,))
        self._x = self._build_x()                     # ("self", "_x")
    """
    donors: Dict[Tuple[str, str], Tuple[int, ...]] = {}
    builder_methods: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        positions: Tuple[int, ...] = ()
        target_call = None
        if name in _JIT_NAMES:
            target_call = node
        elif name in _PARTIAL_NAMES and node.args:
            if dotted_name(node.args[0]) in _JIT_NAMES:
                target_call = node
        if target_call is None:
            continue
        for kw in target_call.keywords:
            if kw.arg == "donate_argnums":
                positions = _donate_positions(kw.value)
        if not positions:
            continue
        parent = ctx.parents.get(id(node))
        if isinstance(parent, ast.Assign):
            for tgt in parent.targets:
                if isinstance(tgt, ast.Name):
                    donors[("name", tgt.id)] = positions
        elif isinstance(parent, _FUNC_NODES) and node in parent.decorator_list:
            donors[("name", parent.name)] = positions
        elif isinstance(parent, ast.Return):
            # find the enclosing method: a builder returning a donor
            cur = parent
            while cur is not None and not isinstance(cur, _FUNC_NODES):
                cur = ctx.parents.get(id(cur))
            if cur is not None:
                builder_methods[cur.name] = positions
    if builder_methods:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Assign) and isinstance(node.value, ast.Call)):
                continue
            callee = dotted_name(node.value.func)
            if callee is None or not callee.startswith("self."):
                continue
            meth = callee.split(".", 1)[1]
            if meth in builder_methods:
                for tgt in node.targets:
                    if (
                        isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self"
                    ):
                        donors[("self", tgt.attr)] = builder_methods[meth]
    return donors


def _local_name_events(fn: ast.AST) -> List[Tuple[str, int, bool]]:
    """(name, lineno, is_store) for every local Name in ``fn``'s own scope."""
    events: List[Tuple[str, int, bool]] = []
    for node in walk_local(fn):
        if isinstance(node, ast.Name):
            events.append(
                (node.id, node.lineno, isinstance(node.ctx, (ast.Store, ast.Del)))
            )
    return events


def rule_tm102_donated_reuse(ctx: ModuleCtx, index: RepoIndex) -> Iterable[Finding]:
    donors = _donating_callables(ctx)
    if not donors:
        return
    for fn in _iter_functions(ctx.tree):
        events = None
        for node in walk_local(fn):
            if not isinstance(node, ast.Call):
                continue
            key = None
            if isinstance(node.func, ast.Name):
                key = ("name", node.func.id)
            elif (
                isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "self"
            ):
                key = ("self", node.func.attr)
            if key is None or key not in donors:
                continue
            for pos in donors[key]:
                if pos >= len(node.args):
                    continue
                arg = node.args[pos]
                if not isinstance(arg, ast.Name):
                    continue
                if events is None:
                    events = _local_name_events(fn)
                call_line = node.lineno
                # reads inside the (possibly multi-line) call itself are
                # the donation, not a reuse
                call_end = getattr(node, "end_lineno", None) or call_line
                kills = [
                    ln
                    for (nm, ln, st) in events
                    if nm == arg.id and st and ln >= call_line
                ]
                first_kill = min(kills) if kills else float("inf")
                reads = [
                    ln
                    for (nm, ln, st) in events
                    if nm == arg.id and not st and call_end < ln < first_kill
                ]
                if reads:
                    yield ctx.finding(
                        "TM102",
                        node,
                        scope_of(ctx, node),
                        f"{arg.id!r} is donated to this jitted call "
                        f"(donate_argnums position {pos}) but read again at "
                        f"line {min(reads)}; donated buffers are invalid "
                        f"after the call",
                    )


# --------------------------------------------------------------------------
# TM103: host syncs in hot-path modules
# --------------------------------------------------------------------------

_SYNC_DOTTED = {
    "jax.block_until_ready",
    "np.asarray",
    "numpy.asarray",
    "np.array",
    "numpy.array",
}
_LOOP_NODES = (
    ast.For,
    ast.AsyncFor,
    ast.While,
    ast.ListComp,
    ast.SetComp,
    ast.DictComp,
    ast.GeneratorExp,
)


class _HostSyncVisitor(ast.NodeVisitor):
    def __init__(self, ctx: ModuleCtx):
        self.ctx = ctx
        self.loop_depth = 0
        self.findings: List[Finding] = []

    def _flag(self, node: ast.AST, message: str) -> None:
        self.findings.append(
            self.ctx.finding("TM103", node, scope_of(self.ctx, node), message)
        )

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        name = dotted_name(func)
        if isinstance(func, ast.Attribute) and func.attr == "item" and not node.args:
            self._flag(node, "host sync: .item() copies device -> host")
        elif name in _SYNC_DOTTED:
            self._flag(
                node,
                f"host sync: {name}() blocks until the device value "
                f"materializes on host",
            )
        elif (
            isinstance(func, ast.Name)
            and func.id in ("int", "float", "bool")
            and self.loop_depth > 0
            and len(node.args) == 1
            and isinstance(node.args[0], ast.Call)
        ):
            self._flag(
                node,
                f"host sync inside a loop: {func.id}() on a fresh device "
                f"value serializes dispatch behind compute; accumulate on "
                f"device and convert once after the loop",
            )
        self.generic_visit(node)

    def generic_visit(self, node: ast.AST) -> None:
        if isinstance(node, _LOOP_NODES):
            self.loop_depth += 1
            super().generic_visit(node)
            self.loop_depth -= 1
        elif isinstance(node, _FUNC_NODES) and self.loop_depth:
            # a def inside a loop runs lazily; reset loop context for it
            saved, self.loop_depth = self.loop_depth, 0
            super().generic_visit(node)
            self.loop_depth = saved
        else:
            super().generic_visit(node)


def rule_tm103_host_sync(ctx: ModuleCtx, index: RepoIndex) -> Iterable[Finding]:
    if not ctx.is_hot:
        return []
    v = _HostSyncVisitor(ctx)
    v.visit(ctx.tree)
    return v.findings


# --------------------------------------------------------------------------
# TM201: pallas_call must plumb interpret=
# --------------------------------------------------------------------------


def rule_tm201_pallas_interpret(ctx: ModuleCtx, index: RepoIndex) -> Iterable[Finding]:
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call) and _is_pallas_call(node)):
            continue
        kw_names = {kw.arg for kw in node.keywords}
        if "interpret" in kw_names or None in kw_names:  # None == **kwargs
            continue
        yield ctx.finding(
            "TM201",
            node,
            scope_of(ctx, node),
            "pallas_call without interpret=; plumb an interpret flag "
            "through so the oracle tests can run this kernel on CPU",
        )


# --------------------------------------------------------------------------
# TM202: pallas entry points must be registered with an oracle
# --------------------------------------------------------------------------


def _module_pallas_oracles(ctx: ModuleCtx) -> Optional[Tuple[ast.Assign, Dict[str, str]]]:
    for node in ctx.tree.body:
        if not isinstance(node, ast.Assign):
            continue
        if not any(
            isinstance(t, ast.Name) and t.id == "PALLAS_ORACLES" for t in node.targets
        ):
            continue
        mapping: Dict[str, str] = {}
        if isinstance(node.value, ast.Dict):
            for k, v in zip(node.value.keys, node.value.values):
                if (
                    isinstance(k, ast.Constant)
                    and isinstance(k.value, str)
                    and isinstance(v, ast.Constant)
                    and isinstance(v.value, str)
                ):
                    mapping[k.value] = v.value
        return node, mapping
    return None


def rule_tm202_oracle_registry(ctx: ModuleCtx, index: RepoIndex) -> Iterable[Finding]:
    entry_points = [
        fn
        for fn in ctx.tree.body
        if isinstance(fn, ast.FunctionDef)
        and not fn.name.startswith("_")
        and any(
            isinstance(n, ast.Call) and _is_pallas_call(n) for n in walk_local(fn)
        )
    ]
    if not entry_points:
        return
    registry = _module_pallas_oracles(ctx)
    if registry is None:
        for fn in entry_points:
            yield ctx.finding(
                "TM202",
                fn,
                fn.name,
                f"pallas entry point {fn.name!r} but the module has no "
                f"PALLAS_ORACLES registry mapping it to a kernels/ref.py "
                f"oracle (aggregated by repro.kernels.registry)",
            )
        return
    assign, mapping = registry
    for fn in entry_points:
        if fn.name not in mapping:
            yield ctx.finding(
                "TM202",
                fn,
                fn.name,
                f"pallas entry point {fn.name!r} missing from PALLAS_ORACLES; "
                f"every kernel needs a registered bit-exact oracle",
            )
    if index.has_ref_module:
        for kernel, oracle in mapping.items():
            if oracle not in index.ref_functions:
                yield ctx.finding(
                    "TM202",
                    assign,
                    scope_of(ctx, assign),
                    f"PALLAS_ORACLES maps {kernel!r} to {oracle!r}, which is "
                    f"not defined in kernels/ref.py",
                )


# --------------------------------------------------------------------------
# TM203: no raw // or % in pallas wrappers
# --------------------------------------------------------------------------


def rule_tm203_grid_helpers(ctx: ModuleCtx, index: RepoIndex) -> Iterable[Finding]:
    for fn in _iter_functions(ctx.tree):
        has_pallas = any(
            isinstance(n, ast.Call) and _is_pallas_call(n) for n in walk_local(fn)
        )
        if not has_pallas:
            continue
        for node in walk_local(fn):
            if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.FloorDiv, ast.Mod)
            ):
                op = "//" if isinstance(node.op, ast.FloorDiv) else "%"
                yield ctx.finding(
                    "TM203",
                    node,
                    scope_of(ctx, node),
                    f"raw {op!r} in a pallas wrapper; derive grids and "
                    f"block checks from repro.kernels.shapes "
                    f"(grid_blocks/cdiv/round_up) so the padding contract "
                    f"stays in one place",
                )


# --------------------------------------------------------------------------
# TM301: no blocking calls inside async def
# --------------------------------------------------------------------------

_BLOCKING_DOTTED = {
    "time.sleep",
    "subprocess.run",
    "subprocess.call",
    "subprocess.check_call",
    "subprocess.check_output",
}


def _blocking_reason(node: ast.Call) -> Optional[str]:
    name = dotted_name(node.func)
    if name in _BLOCKING_DOTTED:
        return f"{name}() blocks the event loop; use an async equivalent"
    if not isinstance(node.func, ast.Attribute):
        return None
    attr = node.func.attr
    if attr == "shutdown":
        wait = None
        if node.args and isinstance(node.args[0], ast.Constant):
            wait = node.args[0].value
        for kw in node.keywords:
            if kw.arg == "wait" and isinstance(kw.value, ast.Constant):
                wait = kw.value.value
        if wait is False:
            return None
        return (
            "executor.shutdown(wait=True) joins worker threads on the "
            "event loop; use await asyncio.to_thread(ex.shutdown, True)"
        )
    if attr == "join" and not node.args and not node.keywords:
        return ".join() blocks the event loop (str.join always takes an argument)"
    if attr == "result" and len(node.args) <= 1:
        return (
            ".result() on a concurrent future blocks the event loop; "
            "await asyncio.wrap_future(...) instead"
        )
    if attr == "acquire" and not node.args and not node.keywords:
        return ".acquire() blocks the event loop; use an asyncio.Lock"
    return None


def rule_tm301_blocking_in_async(ctx: ModuleCtx, index: RepoIndex) -> Iterable[Finding]:
    for fn in _iter_functions(ctx.tree):
        if not isinstance(fn, ast.AsyncFunctionDef):
            continue
        for node in walk_local(fn):
            if not isinstance(node, ast.Call):
                continue
            # `await sem.acquire()` etc. are asyncio primitives, not blocks
            if isinstance(ctx.parents.get(id(node)), ast.Await):
                continue
            reason = _blocking_reason(node)
            if reason:
                yield ctx.finding(
                    "TM301", node, scope_of(ctx, node), f"blocking call in async def: {reason}"
                )


# --------------------------------------------------------------------------
# TM302: MicrobatchScheduler state only via methods
# --------------------------------------------------------------------------

_SCHEDULER_PRIVATE = {"_queues", "_depths", "_last_served"}


def rule_tm302_scheduler_encapsulation(
    ctx: ModuleCtx, index: RepoIndex
) -> Iterable[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Attribute):
            continue
        if node.attr not in _SCHEDULER_PRIVATE:
            continue
        if isinstance(node.value, ast.Name) and node.value.id in ("self", "cls"):
            continue
        yield ctx.finding(
            "TM302",
            node,
            scope_of(ctx, node),
            f"direct access to scheduler internal {node.attr!r}; "
            f"MicrobatchScheduler state is a pure state machine — go "
            f"through its methods (submit/pop_batch/depth/...) so admission "
            f"accounting can't be bypassed",
        )


# --------------------------------------------------------------------------
# TM303: ServingEngine registry mutated only by the lifecycle methods
# --------------------------------------------------------------------------

#: The registry attribute and the only scopes allowed to mutate it.  The
#: engine's hot-swap atomicity (ARCHITECTURE.md §Lifecycle) rests on
#: every install going through register/swap/rollback under the engine
#: lock with a version stamp; a stray ``engine._servables[...] = entry``
#: would install unstamped weights invisible to in-flight accounting.
_ENGINE_REGISTRY = "_servables"
_ENGINE_MUTATORS = {"__init__", "register", "swap", "rollback"}
_MUTATING_METHODS = {"pop", "clear", "update", "setdefault", "popitem"}


def rule_tm303_engine_registry(
    ctx: ModuleCtx, index: RepoIndex
) -> Iterable[Finding]:
    def is_self_access(attr: ast.Attribute) -> bool:
        return isinstance(attr.value, ast.Name) and attr.value.id in (
            "self", "cls"
        )

    def allowed_scope(node: ast.AST) -> bool:
        return scope_of(ctx, node).split(".")[-1] in _ENGINE_MUTATORS

    seen: Set[int] = set()   # attr nodes already reported via their stmt
    for node in ast.walk(ctx.tree):
        # engine._servables[...] = ... / del engine._servables[...] —
        # subscript stores and deletes on the registry.
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.Delete)):
            targets = (
                node.targets if isinstance(node, ast.Delete)
                else getattr(node, "targets", None) or [node.target]
            )
            for tgt in targets:
                if not isinstance(tgt, ast.Subscript):
                    continue
                attr = tgt.value
                if (
                    isinstance(attr, ast.Attribute)
                    and attr.attr == _ENGINE_REGISTRY
                    and not (is_self_access(attr) and allowed_scope(node))
                ):
                    seen.add(id(attr))
                    yield ctx.finding(
                        "TM303",
                        node,
                        scope_of(ctx, node),
                        f"mutation of ServingEngine.{_ENGINE_REGISTRY} "
                        f"outside register/swap/rollback; installs must go "
                        f"through the lifecycle API so every version is "
                        f"stamped and swapped under the engine lock",
                    )
        # engine._servables.pop/clear/update(...) — mutating dict methods.
        elif isinstance(node, ast.Call):
            f = node.func
            if (
                isinstance(f, ast.Attribute)
                and f.attr in _MUTATING_METHODS
                and isinstance(f.value, ast.Attribute)
                and f.value.attr == _ENGINE_REGISTRY
                and not (is_self_access(f.value) and allowed_scope(node))
            ):
                seen.add(id(f.value))
                yield ctx.finding(
                    "TM303",
                    node,
                    scope_of(ctx, node),
                    f"ServingEngine.{_ENGINE_REGISTRY}.{f.attr}() outside "
                    f"register/swap/rollback; go through the lifecycle API",
                )
        # Any non-self read of the registry from another module/object —
        # the registry is private to the engine's own methods.
        elif isinstance(node, ast.Attribute):
            if (
                node.attr == _ENGINE_REGISTRY
                and not is_self_access(node)
                and id(node) not in seen
            ):
                yield ctx.finding(
                    "TM303",
                    node,
                    scope_of(ctx, node),
                    f"direct access to ServingEngine.{_ENGINE_REGISTRY}; "
                    f"use models()/servable()/version()/stats() (reads) or "
                    f"register()/swap()/rollback() (installs)",
                )


# --------------------------------------------------------------------------
# TM304: serve/ must not swallow exceptions silently
# --------------------------------------------------------------------------

#: The serving spine's request-lifetime guarantee (ARCHITECTURE.md
#: §Faults) is that every fault either propagates, resolves a request
#: future, or lands in an observable sink (stats / ServiceHealth / a
#: FaultPlan counter).  A broad ``except Exception: pass`` anywhere in
#: serve/ is how futures hang and faults vanish — the exact failure mode
#: the chaos suite exists to rule out.
_BROAD_EXC_NAMES = {"Exception", "BaseException"}
#: Identifier substrings that count as an observability sink: mutating
#: stats/health/fault counters, or routing through the service's
#: _record_*/_note_*/_fail_* helpers.
_SINK_MARKERS = (
    "stat", "health", "fault", "record", "note", "quarantin", "expired",
    "fail", "reject", "log",
)


def _is_broad_handler_type(node: Optional[ast.AST]) -> bool:
    if node is None:            # bare except:
        return True
    if isinstance(node, ast.Tuple):
        return any(_is_broad_handler_type(e) for e in node.elts)
    name = dotted_name(node)
    return name is not None and name.split(".")[-1] in _BROAD_EXC_NAMES


def _handler_has_sink(handler: ast.ExceptHandler) -> bool:
    """True when the handler re-raises, resolves a future, or touches an
    identifier that reads as a stats/health/fault sink.  Nested defs are
    not descended into (they run later, if ever — a sink defined but not
    executed in the handler is no sink)."""
    nodes: List[ast.AST] = []
    for stmt in handler.body:
        nodes.append(stmt)
        if not isinstance(stmt, _NESTED_SCOPES):
            nodes.extend(walk_local(stmt))
    for node in nodes:
            if isinstance(node, ast.Raise):
                return True
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("set_exception", "set_result")
            ):
                return True
            ident = None
            if isinstance(node, ast.Attribute):
                ident = node.attr
            elif isinstance(node, ast.Name):
                ident = node.id
            if ident is not None and any(
                m in ident.lower() for m in _SINK_MARKERS
            ):
                return True
    return False


def rule_tm304_serve_swallowed_exceptions(
    ctx: ModuleCtx, index: RepoIndex
) -> Iterable[Finding]:
    rel = ctx.relpath
    if "repro/serve/" not in rel and not rel.startswith("serve/"):
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if not _is_broad_handler_type(node.type):
            continue
        if _handler_has_sink(node):
            continue
        yield ctx.finding(
            "TM304",
            node,
            scope_of(ctx, node),
            "broad except swallows the failure: re-raise, resolve the "
            "request future (set_exception/set_result), or record it to a "
            "stats/health/fault sink — serve/ futures must resolve and "
            "faults must be observable (ARCHITECTURE.md §Faults)",
        )


ALL_RULES = [
    rule_tm101_static_hashable,
    rule_tm102_donated_reuse,
    rule_tm103_host_sync,
    rule_tm201_pallas_interpret,
    rule_tm202_oracle_registry,
    rule_tm203_grid_helpers,
    rule_tm301_blocking_in_async,
    rule_tm302_scheduler_encapsulation,
    rule_tm303_engine_registry,
    rule_tm304_serve_swallowed_exceptions,
]
