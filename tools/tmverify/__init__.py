"""tmverify: IR-level contract verification for the jitted TM paths.

Where ``tools/tmlint`` checks contracts at the **AST** level (what the
source says), tmverify checks them at the **IR** level (what the lowered
program actually does): it enumerates every registered (EvalPath x input
form x bucket) jitted step from ``serve/paths.py`` / ``serve/engine.py``
plus the ``TrainerEngine`` epoch step, lowers each via ``.trace()`` /
``.lower()``, and runs five analyses:

  * **TM401** — donation audit: every declared ``donate_argnums`` leaf
    produces real input->output aliasing in the lowered module (a
    silently dropped donation doubles the hot path's memory traffic).
  * **TM402** — host-transfer freedom: no callback / infeed / outfeed
    primitives anywhere in a serve-path jaxpr (a host round trip inside
    a dispatch stalls the whole pipeline).
  * **TM403** — recompile-key audit: the path registry's static args
    give a bounded, hashable jit-cache cardinality per (path, form) —
    an unhashable or unbounded key is a recompile storm waiting for
    traffic.
  * **TM404** — integer-range interval analysis over the clause-eval /
    class-sum jaxprs, proving the int8 x int8 -> int32 accumulators,
    the uint32 popcount chains and the fp32 class-sum tiles cannot
    overflow (or lose exactness) at ``repro.core.cotm.MAX_GEOMETRY``.
  * **TM405** — Pallas grid/VMEM budget: for every ``pl.pallas_call``,
    block footprints recomputed from its BlockSpecs via
    ``kernels/shapes.py`` must cover the padded operands exactly and
    fit a configurable VMEM budget.

Run as ``python -m tools.tmverify src/repro``; the committed report
lives at ``tools/tmverify/REPORT.md`` (freshness-gated by
``tests/test_tmverify.py``) and accepted findings carry justifications
in ``tools/tmverify/baseline.json``.
"""
