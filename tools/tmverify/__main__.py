"""CLI: ``python -m tools.tmverify src/repro``.

Exit codes (same contract as tools/tmlint):
  0 — all checks passed (modulo baseline waivers, none stale)
  1 — unsuppressed findings
  2 — stale baseline waivers (entries matching nothing; prune them)
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

DEFAULT_BASELINE = Path(__file__).parent / "baseline.json"


def _ensure_src_on_path(paths) -> None:
    """Make ``repro`` importable from the positional path argument (the
    CLI is invoked from the repo root as ``python -m tools.tmverify
    src/repro``; tests import us with PYTHONPATH already set)."""
    for arg in paths:
        p = Path(arg).resolve()
        if p.name == "repro" and p.is_dir():  # namespace pkg: no __init__
            root = str(p.parent)
            if root not in sys.path:
                sys.path.insert(0, root)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.tmverify",
        description="IR-level contract verification of the jitted "
        "serve/train paths (TM401-TM405).",
    )
    ap.add_argument(
        "paths", nargs="*", default=["src/repro"],
        help="package path to verify (locates the repro source root)",
    )
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                    help="waiver baseline JSON (default: committed)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline entirely")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    ap.add_argument("--report", action="store_true",
                    help="print the full markdown report to stdout")
    ap.add_argument("--max-batch", type=int, default=32,
                    help="serve bucket range endpoint (default 32)")
    ap.add_argument("--vmem-budget", type=int, default=16 * 1024 * 1024,
                    help="TM405 VMEM budget in bytes (default 16 MiB)")
    args = ap.parse_args(argv)

    from tools.tmverify.core import RULE_DOCS, Baseline

    if args.list_rules:
        for rule in sorted(RULE_DOCS):
            print(f"{rule}: {RULE_DOCS[rule]}")
        return 0

    _ensure_src_on_path(args.paths)

    from tools.tmverify.report import render_report
    from tools.tmverify.run import run_verify
    from tools.tmverify.targets import VerifyConfig

    if args.no_baseline or not args.baseline.exists():
        baseline = Baseline.empty()
    else:
        baseline = Baseline.load(args.baseline)

    vcfg = VerifyConfig(
        max_batch=args.max_batch, vmem_budget=args.vmem_budget
    )
    result = run_verify(vcfg, baseline)

    if args.report:
        sys.stdout.write(render_report(result, vcfg))
    else:
        for f in result.findings:
            print(f.render())
        print(
            f"tmverify: {len(result.targets)} targets, "
            f"{result.checks} checks, {len(result.findings)} finding(s), "
            f"{len(result.suppressed)} suppressed",
            file=sys.stderr,
        )

    if result.findings:
        return 1
    if result.stale_baseline:
        for e in result.stale_baseline:
            print(
                f"stale waiver: {e['rule']} [{e['target']}] {e['key']}",
                file=sys.stderr,
            )
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
