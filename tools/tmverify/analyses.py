"""TM401-TM403: donation, host-transfer and recompile-key audits.

Each rule has a pure core (operating on lowered text / a jaxpr / a
path-like object) so tests can drive negative fixtures directly, plus a
``check_*`` driver that walks the enumerated targets or the live path
registry and files findings into a :class:`~tools.tmverify.core.VerifyResult`.
"""

from __future__ import annotations

from typing import Iterable, Iterator, List, Sequence, Tuple

from tools.tmverify.core import Baseline, Finding, VerifyResult
from tools.tmverify.targets import StepTarget, VerifyConfig, buckets_for

__all__ = [
    "aliased_output_count",
    "audit_registry_path",
    "check_donation",
    "check_host_transfers",
    "check_recompile_keys",
    "forbidden_primitives",
    "iter_eqns",
]

# ---------------------------------------------------------------------------
# TM401 — donation audit


def aliased_output_count(lowered_text: str) -> int:
    """How many input->output aliases the lowered module actually carries.

    XLA marks each honoured donation with a ``tf.aliasing_output`` arg
    attribute in the StableHLO module; a donation jit accepted but could
    not alias (dtype/shape mismatch, consumed-after-donate, platform
    refusal) simply has no attribute — which is exactly the silent drop
    this rule exists to catch.
    """
    return lowered_text.count("tf.aliasing_output")


def check_donation(
    targets: Sequence[StepTarget], result: VerifyResult, baseline: Baseline
) -> None:
    lines = result.summary.setdefault("TM401", [])
    donating = [t for t in targets if t.donated_leaves > 0]
    if not donating:
        lines.append(
            "no target declares donation on this backend "
            "(CPU: engine declares none by design); nothing to audit"
        )
    for t in donating:
        result.checks += 1
        realized = aliased_output_count(t.lowered_text())
        lines.append(
            f"{t.name}: declared {t.donated_leaves} donated "
            f"leaves, lowered module aliases {realized}"
        )
        if realized < t.donated_leaves:
            result.add(baseline, Finding(
                "TM401", t.name,
                f"dropped:{realized}of{t.donated_leaves}",
                f"declares {t.donated_leaves} donated leaves but the "
                f"lowered module aliases only {realized} — donation was "
                f"silently dropped",
            ))
    # One representative compile proves the aliasing survives past
    # lowering into the executable (attributes can in principle be
    # discarded by the compiler); the trainer epoch step is the one
    # donating target on every backend.
    train = [t for t in donating if t.kind == "train"]
    if train:
        t = train[0]
        result.checks += 1
        compiled = t.traced.lower().compile()
        donate = tuple(getattr(compiled, "donate_argnums", ()) or ())
        aliased = "input_output_alias" in compiled.as_text()
        lines.append(
            f"{t.name}: compiled donate_argnums={donate}, "
            f"executable input_output_alias={'yes' if aliased else 'no'}"
        )
        if not donate or not aliased:
            result.add(baseline, Finding(
                "TM401", t.name, "compile:no-alias",
                "compiled executable shows no input_output_alias for the "
                "declared donation",
            ))


# ---------------------------------------------------------------------------
# TM402 — host-transfer freedom

#: Primitive names that imply a host round trip inside the jitted step.
#: ``device_put`` is NOT here: it appears benignly for weight constants
#: staged into the trace and does not stall dispatch.
_HOST_PRIM_EXACT = frozenset({"infeed", "outfeed", "outside_call"})
_HOST_PRIM_SUBSTRING = "callback"


def iter_eqns(jaxpr) -> Iterator:
    """Every eqn in ``jaxpr`` and all nested sub-jaxprs (pjit bodies,
    scan/cond/while branches), depth-first."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from iter_eqns(sub)


def _subjaxprs(v) -> List:
    if hasattr(v, "eqns"):          # open Jaxpr
        return [v]
    if hasattr(v, "jaxpr"):         # ClosedJaxpr
        return [v.jaxpr]
    if isinstance(v, (list, tuple)):
        out: List = []
        for e in v:
            out.extend(_subjaxprs(e))
        return out
    return []


def forbidden_primitives(jaxpr) -> List[str]:
    """Names of host-transfer primitives found anywhere in the jaxpr."""
    bad = []
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name in _HOST_PRIM_EXACT or _HOST_PRIM_SUBSTRING in name:
            bad.append(name)
    return bad


def check_host_transfers(
    targets: Sequence[StepTarget], result: VerifyResult, baseline: Baseline
) -> None:
    lines = result.summary.setdefault("TM402", [])
    serve = [t for t in targets if t.kind == "serve"]
    prims_seen = set()
    clean = 0
    for t in serve:
        result.checks += 1
        jx = t.jaxpr
        open_jaxpr = jx.jaxpr if hasattr(jx, "jaxpr") else jx
        for eqn in iter_eqns(open_jaxpr):
            prims_seen.add(eqn.primitive.name)
        bad = forbidden_primitives(open_jaxpr)
        if bad:
            result.add(baseline, Finding(
                "TM402", t.name, f"host:{','.join(sorted(set(bad)))}",
                f"serve-path jaxpr contains host-transfer primitives: "
                f"{sorted(set(bad))}",
            ))
        else:
            clean += 1
    lines.append(
        f"{clean}/{len(serve)} serve steps free of host-transfer "
        f"primitives"
    )
    lines.append(
        "primitive closure across all serve jaxprs: "
        + ", ".join(sorted(prims_seen))
    )


# ---------------------------------------------------------------------------
# TM403 — recompile-key audit


def audit_registry_path(
    path, spec, *, n_buckets: int, n_forms: int, cap: int
) -> Tuple[List[Finding], int]:
    """Findings + worst-case per-(path, form) cache cardinality for one
    path-like object (``name`` / ``tunable`` / ``ingress_spec`` /
    ``input_form`` / ``fallback`` attributes — tests pass synthetic
    stand-ins)."""
    findings: List[Finding] = []
    target = f"registry:{path.name}"

    tunable = path.tunable
    if not isinstance(tunable, tuple):
        findings.append(Finding(
            "TM403", target, "tunable:not-tuple",
            f"tunable is {type(tunable).__name__}, not a finite tuple — "
            f"cache cardinality is unbounded/unauditable",
        ))
        tunable = ()
    for i, cand in enumerate(tunable):
        ok_shape = isinstance(cand, tuple) and all(
            isinstance(p, tuple) and len(p) == 2 and isinstance(p[0], str)
            for p in cand
        )
        if not ok_shape:
            findings.append(Finding(
                "TM403", target, f"params:{i}:malformed",
                f"tunable[{i}] is not a ((name, value), ...) tuple: "
                f"{cand!r}",
            ))
            continue
        try:
            hash(cand)
        except TypeError:
            findings.append(Finding(
                "TM403", target, f"params:{i}:unhashable",
                f"tunable[{i}] is unhashable and would raise at dispatch "
                f"(jit static args must hash): {cand!r}",
            ))
    try:
        hash(path.ingress_spec(spec))
    except TypeError:
        findings.append(Finding(
            "TM403", target, "ingress:unhashable",
            "ingress_spec(spec) is unhashable; the raw step keys its jit "
            "cache on it",
        ))
    if getattr(path, "fallback", None) is not None:
        from repro.serve.paths import available_paths, get_path

        if path.fallback not in available_paths():
            findings.append(Finding(
                "TM403", target, "fallback:unregistered",
                f"fallback {path.fallback!r} is not a registered path",
            ))
        elif get_path(path.fallback).input_form != path.input_form:
            findings.append(Finding(
                "TM403", target, "fallback:form-mismatch",
                f"fallback {path.fallback!r} has a different input form; "
                f"substitution would change the conversion done per "
                f"request",
            ))
    cardinality = n_buckets * max(1, len(tunable))
    if cardinality > cap:
        findings.append(Finding(
            "TM403", target, f"cardinality:{cardinality}",
            f"worst-case jit-cache cardinality per (path, form) is "
            f"{cardinality} (= {n_buckets} buckets x {max(1, len(tunable))} "
            f"param sets) > cap {cap}",
        ))
    return findings, cardinality


def check_recompile_keys(
    vcfg: VerifyConfig, result: VerifyResult, baseline: Baseline
) -> None:
    from repro.serve.paths import available_paths, get_path
    from tools.tmverify.targets import tiny_config

    lines = result.summary.setdefault("TM403", [])
    spec = tiny_config().patch
    n_buckets = len(buckets_for(vcfg.engine_max_batch))
    total = 0
    for name in available_paths():
        result.checks += 1
        findings, card = audit_registry_path(
            get_path(name), spec,
            n_buckets=n_buckets, n_forms=2, cap=vcfg.cardinality_cap,
        )
        for f in findings:
            result.add(baseline, f)
        total += card * 2  # literals + raw forms
        lines.append(
            f"{name}: <= {card} cache keys per form "
            f"({n_buckets} buckets x {max(1, len(get_path(name).tunable))} "
            f"param sets), cap {vcfg.cardinality_cap}"
        )
    lines.append(
        f"whole-registry worst case across both forms: {total} compiled "
        f"steps at engine max_batch={vcfg.engine_max_batch}"
    )
