"""tmverify core: findings, waiver baseline, result container, runner.

Mirrors ``tools/tmlint/core.py``'s machinery where the two tools agree
(fingerprinted baseline entries with mandatory justifications, stale
detection, exit-code contract) but fingerprints name **verify targets**
— lowered jitted steps and kernel jaxprs — instead of source lines:
``(rule, target, key)``, where ``target`` is a stable target id like
``serve:fused:raw:b8`` and ``key`` a short detail slug.  Line numbers
never enter the identity because the subjects are IR artifacts, not
source locations.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Sequence, Set, Tuple

__all__ = ["Finding", "Baseline", "VerifyResult", "RULE_DOCS"]

RULE_DOCS = {
    "TM401": (
        "donation audit: declared donate_argnums leaves must produce real "
        "input->output aliasing in the lowered module"
    ),
    "TM402": (
        "host-transfer freedom: no callback/infeed/outfeed primitives in "
        "any serve-path jaxpr"
    ),
    "TM403": (
        "recompile-key audit: path-registry static args must be hashable "
        "with bounded jit-cache cardinality per (path, form)"
    ),
    "TM404": (
        "integer-range interval analysis: accumulator chains must be "
        "overflow-free (and fp32 tiles exact) at MAX_GEOMETRY"
    ),
    "TM405": (
        "Pallas grid/VMEM budget: BlockSpec grids must cover padded "
        "operands exactly and resident footprints must fit the VMEM budget"
    ),
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verification failure; ``fingerprint()`` is the waiver identity."""

    rule: str
    target: str     # verify target id, e.g. "serve:fused:raw:b8"
    key: str        # short detail slug, the stable part of the identity
    message: str

    def fingerprint(self) -> Tuple[str, str, str]:
        return (self.rule, self.target, self.key)

    def render(self) -> str:
        return f"{self.rule} [{self.target}] {self.message}"


class Baseline:
    """Committed waivers for accepted findings.

    JSON shape::

        {"version": 1,
         "waivers": [
            {"rule": "TM401", "target": "train:epoch",
             "key": "dropped:ta_state",
             "justification": "why this is accepted"},
            ...]}

    Every entry MUST carry a non-empty justification — a waiver is a
    reviewed decision, not a mute button.
    """

    def __init__(self, entries: Sequence[dict]):
        self._entries = list(entries)
        self._index: Dict[Tuple[str, str, str], dict] = {}
        for i, e in enumerate(entries):
            missing = {"rule", "target", "key"} - set(e)
            if missing:
                raise ValueError(
                    f"baseline entry {i} missing keys: {sorted(missing)}"
                )
            if not str(e.get("justification", "")).strip():
                raise ValueError(
                    f"baseline entry {i} ({e['rule']} {e['target']}) has no "
                    f"justification; every waiver must say why"
                )
            self._index[(e["rule"], e["target"], e["key"])] = e
        self._hits: Set[Tuple[str, str, str]] = set()

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        data = json.loads(path.read_text(encoding="utf-8"))
        if data.get("version") != 1:
            raise ValueError(
                f"unsupported baseline version: {data.get('version')!r}"
            )
        return cls(data.get("waivers", []))

    @classmethod
    def empty(cls) -> "Baseline":
        return cls([])

    def suppresses(self, finding: Finding) -> bool:
        key = finding.fingerprint()
        if key in self._index:
            self._hits.add(key)
            return True
        return False

    def stale_entries(self) -> List[dict]:
        """Waivers that matched no finding — candidates for removal."""
        return [
            e for e in self._entries
            if (e["rule"], e["target"], e["key"]) not in self._hits
        ]


@dataclasses.dataclass
class VerifyResult:
    findings: List[Finding]       # unsuppressed (these fail the run)
    suppressed: List[Finding]     # matched a baseline waiver
    stale_baseline: List[dict]    # waivers that matched nothing
    targets: List[str]            # every target id enumerated, in order
    checks: int                   # individual checks evaluated
    #: per-rule machine-readable summary lines for REPORT.md (rule -> lines)
    summary: Dict[str, List[str]] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.findings

    def add(self, baseline: Baseline, finding: Finding) -> None:
        (self.suppressed if baseline.suppresses(finding)
         else self.findings).append(finding)
