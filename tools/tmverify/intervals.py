"""TM404: integer-range interval analysis over clause-eval / class-sum
jaxprs.

The analysis walks a jaxpr with one abstract value per array: a single
``[lo, hi]`` interval over the *mathematical* integers bounding every
element.  All values in the TM eval pipeline are integer-valued — even
the bf16/fp32 matmul formulations only ever hold exact small integers —
so one engine proves both contracts:

  * **integer overflow**: an eqn whose mathematical result interval
    escapes its integer output dtype's representable range (e.g. an int8
    accumulator fed more than 127 ones) is a finding; the interval is
    clamped to the dtype range and the walk continues, so one overflow
    does not cascade into noise.
  * **float exactness**: a float-typed value whose magnitude bound
    exceeds the dtype's exact-integer range (bf16: 2^8, fp16: 2^11,
    fp32: 2^24, fp64: 2^53) may round — fatal for the ``viol == 0.0``
    clause-firing compare — and is a finding at the producing eqn or the
    float->int convert.

Primitives without a handler degrade soundly: integer outputs get the
full dtype range (no finding — unknown, not proven wrong), float outputs
get the dtype's exact range.  Axes that are only ever OR-reduced
(batch, patches) don't influence intervals, so the driver traces with
the *contracted* axes (clauses, literal words, classes) at
``repro.core.cotm.MAX_GEOMETRY`` and tiny parallel axes — the proof is
still the envelope proof.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from tools.tmverify.core import Baseline, Finding, VerifyResult

__all__ = [
    "Interval",
    "analyze_fn",
    "check_intervals",
    "dtype_interval",
    "exact_int_bound",
]


@dataclasses.dataclass(frozen=True)
class Interval:
    lo: int
    hi: int

    def __post_init__(self):
        assert self.lo <= self.hi, (self.lo, self.hi)

    def union(self, other: "Interval") -> "Interval":
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi))

    def magnitude(self) -> int:
        return max(abs(self.lo), abs(self.hi))


BOOL01 = Interval(0, 1)

#: Largest N with every integer in [-N, N] exactly representable.
_EXACT_FLOAT_BOUND = {
    "bfloat16": 1 << 8,
    "float16": 1 << 11,
    "float32": 1 << 24,
    "float64": 1 << 53,
}


def exact_int_bound(dtype) -> int:
    return _EXACT_FLOAT_BOUND[np.dtype(dtype).name if np.dtype(dtype).name
                              in _EXACT_FLOAT_BOUND else _bf16_name(dtype)]


def _bf16_name(dtype) -> str:
    # jax's bfloat16 is not a numpy builtin; match by name attribute.
    name = getattr(dtype, "name", str(dtype))
    if name not in _EXACT_FLOAT_BOUND:
        raise KeyError(name)
    return name


def _is_float(dtype) -> bool:
    name = getattr(dtype, "name", str(np.dtype(dtype)))
    return name in _EXACT_FLOAT_BOUND or np.issubdtype(
        np.dtype(dtype) if name != "bfloat16" else np.float32, np.floating
    )


def dtype_interval(dtype) -> Interval:
    """The representable (integer dtypes) or exactly-representable
    (float dtypes) integer interval of ``dtype``."""
    name = getattr(dtype, "name", str(np.dtype(dtype)))
    if name == "bool":
        return BOOL01
    if name in _EXACT_FLOAT_BOUND:
        b = _EXACT_FLOAT_BOUND[name]
        return Interval(-b, b)
    np_dtype = np.dtype(dtype)
    if np.issubdtype(np_dtype, np.floating):
        b = _EXACT_FLOAT_BOUND[np_dtype.name]
        return Interval(-b, b)
    info = np.iinfo(np_dtype)
    return Interval(int(info.min), int(info.max))


def _fits(iv: Interval, dtype) -> bool:
    dr = dtype_interval(dtype)
    return dr.lo <= iv.lo and iv.hi <= dr.hi


def _clamp(iv: Interval, dtype) -> Interval:
    dr = dtype_interval(dtype)
    return Interval(max(iv.lo, dr.lo), min(iv.hi, dr.hi))


def _next_mask(hi: int) -> int:
    """Smallest 2^k - 1 >= hi (bitwise-op upper bound)."""
    m = 0
    while m < hi:
        m = (m << 1) | 1
    return m


# ---------------------------------------------------------------------------
# Primitive transfer functions


def _products(a: Interval, b: Interval) -> Tuple[int, int]:
    ps = [a.lo * b.lo, a.lo * b.hi, a.hi * b.lo, a.hi * b.hi]
    return min(ps), max(ps)


def _dot_general(eqn, ins: List[Interval]) -> Interval:
    (lhs_contract, _), _ = eqn.params["dimension_numbers"]
    lhs_shape = eqn.invars[0].aval.shape
    k = 1
    for d in lhs_contract:
        k *= int(lhs_shape[d])
    pmin, pmax = _products(ins[0], ins[1])
    return Interval(k * pmin, k * pmax)


def _reduce_sum(eqn, ins: List[Interval]) -> Interval:
    shape = eqn.invars[0].aval.shape
    n = 1
    for d in eqn.params["axes"]:
        n *= int(shape[d])
    return Interval(n * ins[0].lo, n * ins[0].hi)


def _bitwise(eqn, ins: List[Interval]) -> Interval:
    if all(iv.lo >= 0 for iv in ins):
        if all(iv.hi <= 1 for iv in ins):
            return BOOL01
        if eqn.primitive.name == "and":
            return Interval(0, min(_next_mask(iv.hi) for iv in ins))
        return Interval(0, max(_next_mask(iv.hi) for iv in ins))
    return dtype_interval(eqn.outvars[0].aval.dtype)


def _shift_left(eqn, ins: List[Interval]) -> Interval:
    s = ins[1]
    if s.lo == s.hi and ins[0].lo >= 0:
        return Interval(ins[0].lo << s.lo, ins[0].hi << s.lo)
    return dtype_interval(eqn.outvars[0].aval.dtype)


def _iota(eqn, ins) -> Interval:
    shape = eqn.params.get("shape") or eqn.outvars[0].aval.shape
    dim = eqn.params.get("dimension", 0)
    return Interval(0, max(0, int(shape[dim]) - 1))


def _argminmax(eqn, ins) -> Interval:
    shape = eqn.invars[0].aval.shape
    axes = eqn.params.get("axes", (0,))
    return Interval(0, max(0, int(shape[axes[0]]) - 1))


_HANDLERS: Dict[str, Callable] = {
    "add": lambda e, i: Interval(i[0].lo + i[1].lo, i[0].hi + i[1].hi),
    "sub": lambda e, i: Interval(i[0].lo - i[1].hi, i[0].hi - i[1].lo),
    "mul": lambda e, i: Interval(*_products(i[0], i[1])),
    "neg": lambda e, i: Interval(-i[0].hi, -i[0].lo),
    "max": lambda e, i: Interval(max(i[0].lo, i[1].lo), max(i[0].hi, i[1].hi)),
    "min": lambda e, i: Interval(min(i[0].lo, i[1].lo), min(i[0].hi, i[1].hi)),
    "dot_general": _dot_general,
    "reduce_sum": _reduce_sum,
    "reduce_max": lambda e, i: i[0],
    "reduce_min": lambda e, i: i[0],
    "reduce_and": lambda e, i: BOOL01,
    "reduce_or": lambda e, i: BOOL01,
    "and": _bitwise,
    "or": _bitwise,
    "xor": _bitwise,
    "not": lambda e, i: (
        BOOL01 if e.outvars[0].aval.dtype == np.dtype(bool)
        else dtype_interval(e.outvars[0].aval.dtype)
    ),
    "population_count": lambda e, i: Interval(
        0, np.dtype(e.invars[0].aval.dtype).itemsize * 8
    ),
    "clz": lambda e, i: Interval(
        0, np.dtype(e.invars[0].aval.dtype).itemsize * 8
    ),
    "shift_left": _shift_left,
    "shift_right_logical": lambda e, i: Interval(0, max(0, i[0].hi)),
    "eq": lambda e, i: BOOL01,
    "ne": lambda e, i: BOOL01,
    "lt": lambda e, i: BOOL01,
    "le": lambda e, i: BOOL01,
    "gt": lambda e, i: BOOL01,
    "ge": lambda e, i: BOOL01,
    "select_n": lambda e, i: _union_all(i[1:]),
    "concatenate": lambda e, i: _union_all(i),
    "pad": lambda e, i: i[0].union(i[1]),
    "broadcast_in_dim": lambda e, i: i[0],
    "reshape": lambda e, i: i[0],
    "transpose": lambda e, i: i[0],
    "squeeze": lambda e, i: i[0],
    "expand_dims": lambda e, i: i[0],
    "copy": lambda e, i: i[0],
    "rev": lambda e, i: i[0],
    "slice": lambda e, i: i[0],
    "dynamic_slice": lambda e, i: i[0],
    "gather": lambda e, i: i[0],
    "device_put": lambda e, i: i[0],
    "stop_gradient": lambda e, i: i[0],
    "iota": _iota,
    "argmax": _argminmax,
    "argmin": _argminmax,
    "integer_pow": lambda e, i: _int_pow(e, i),
    "clamp": lambda e, i: Interval(
        max(i[1].lo, i[0].lo), min(i[1].hi, i[2].hi)
    ) if i[0].lo <= i[2].hi else i[1],
}


def _union_all(ivs: Sequence[Interval]) -> Interval:
    out = ivs[0]
    for iv in ivs[1:]:
        out = out.union(iv)
    return out


def _int_pow(eqn, ins: List[Interval]) -> Interval:
    p = int(eqn.params["y"])
    cands = [ins[0].lo ** p, ins[0].hi ** p]
    if ins[0].lo < 0 < ins[0].hi:
        cands.append(0)
    return Interval(min(cands), max(cands))


# ---------------------------------------------------------------------------
# Jaxpr walk


@dataclasses.dataclass
class IntervalStats:
    eqns: int = 0
    handled: int = 0
    #: widest integer-typed eqn output interval (the proven accumulator
    #: bound reported in REPORT.md)
    widest_int: Optional[Interval] = None

    def note_int(self, iv: Interval) -> None:
        if self.widest_int is None or iv.magnitude() > self.widest_int.magnitude():
            self.widest_int = iv


def _const_interval(val) -> Interval:
    arr = np.asarray(val)
    if arr.dtype == np.dtype(bool):
        arr = arr.astype(np.int64)
    if arr.size == 0:
        return Interval(0, 0)
    return Interval(int(arr.min()), int(arr.max()))


def _walk(jaxpr, env: Dict, target: str, findings: List[Finding],
          stats: IntervalStats, prefix: str = "") -> None:
    def read(atom) -> Interval:
        if hasattr(atom, "val"):          # Literal
            return _const_interval(atom.val)
        return env[atom]

    for idx, eqn in enumerate(jaxpr.eqns):
        stats.eqns += 1
        name = eqn.primitive.name
        key = f"{prefix}{idx}:{name}"
        ins = [read(v) for v in eqn.invars]

        if name == "pjit":
            inner = eqn.params["jaxpr"]
            inner_jaxpr = inner.jaxpr if hasattr(inner, "jaxpr") else inner
            sub_env: Dict = {}
            for cv, cval in zip(inner_jaxpr.constvars,
                                getattr(inner, "consts", [])):
                sub_env[cv] = _const_interval(cval)
            for var, iv in zip(inner_jaxpr.invars, ins):
                sub_env[var] = iv
            _walk(inner_jaxpr, sub_env, target, findings, stats,
                  prefix=f"{key}/")
            for out, inner_out in zip(eqn.outvars, inner_jaxpr.outvars):
                env[out] = (sub_env[inner_out]
                            if not hasattr(inner_out, "val")
                            else _const_interval(inner_out.val))
            continue

        handler = _HANDLERS.get(name)
        if name == "convert_element_type":
            out_dtype = eqn.outvars[0].aval.dtype
            iv = ins[0]
            if _is_float(eqn.invars[0].aval.dtype) and not _is_float(out_dtype):
                # float -> int: the float side must have stayed exact.
                src_bound = exact_int_bound(eqn.invars[0].aval.dtype)
                if iv.magnitude() > src_bound:
                    findings.append(Finding(
                        "TM404", target, f"{key}:inexact-src",
                        f"float->int convert of values in [{iv.lo}, "
                        f"{iv.hi}] whose magnitude exceeds the source "
                        f"dtype's exact-integer bound {src_bound}",
                    ))
                    iv = _clamp(iv, out_dtype)
            if not _is_float(out_dtype) and not _fits(iv, out_dtype):
                findings.append(Finding(
                    "TM404", target, f"{key}:narrowing",
                    f"convert to {out_dtype} of values in [{iv.lo}, "
                    f"{iv.hi}] overflows its range "
                    f"[{dtype_interval(out_dtype).lo}, "
                    f"{dtype_interval(out_dtype).hi}]",
                ))
                iv = _clamp(iv, out_dtype)
            if _is_float(out_dtype) and iv.magnitude() > exact_int_bound(out_dtype):
                findings.append(Finding(
                    "TM404", target, f"{key}:inexact",
                    f"convert to {out_dtype} of integers in [{iv.lo}, "
                    f"{iv.hi}] exceeds the exact-integer bound "
                    f"{exact_int_bound(out_dtype)} — equality compares "
                    f"downstream may misfire",
                ))
            env[eqn.outvars[0]] = iv
            stats.handled += 1
            if not _is_float(out_dtype):
                stats.note_int(iv)
            continue

        if handler is not None:
            iv = handler(eqn, ins)
            stats.handled += 1
        else:
            iv = dtype_interval(eqn.outvars[0].aval.dtype)

        out_dtype = eqn.outvars[0].aval.dtype
        if handler is not None and not _is_float(out_dtype) \
                and str(out_dtype) != "bool" and not _fits(iv, out_dtype):
            findings.append(Finding(
                "TM404", target, f"{key}:overflow",
                f"{name} result interval [{iv.lo}, {iv.hi}] overflows "
                f"{out_dtype} "
                f"[{dtype_interval(out_dtype).lo}, "
                f"{dtype_interval(out_dtype).hi}]",
            ))
            iv = _clamp(iv, out_dtype)
        if handler is not None and _is_float(out_dtype) \
                and iv.magnitude() > exact_int_bound(out_dtype):
            findings.append(Finding(
                "TM404", target, f"{key}:inexact",
                f"{name} result interval [{iv.lo}, {iv.hi}] exceeds "
                f"{out_dtype}'s exact-integer bound "
                f"{exact_int_bound(out_dtype)}",
            ))
        if not _is_float(out_dtype):
            stats.note_int(iv)
        for out in eqn.outvars:
            env[out] = iv


def analyze_fn(
    fn, arg_specs: Sequence, seeds: Sequence[Interval], target: str
) -> Tuple[List[Finding], IntervalStats]:
    """Trace ``fn`` at ``arg_specs`` (ShapeDtypeStructs) and walk the
    jaxpr with per-argument seed intervals."""
    import jax

    closed = jax.make_jaxpr(fn)(*arg_specs)
    jaxpr = closed.jaxpr
    if len(seeds) != len(jaxpr.invars):
        raise ValueError(
            f"{target}: {len(seeds)} seeds for {len(jaxpr.invars)} invars"
        )
    env: Dict = {}
    for cv, cval in zip(jaxpr.constvars, closed.consts):
        env[cv] = _const_interval(cval)
    for var, iv in zip(jaxpr.invars, seeds):
        env[var] = iv
    findings: List[Finding] = []
    stats = IntervalStats()
    _walk(jaxpr, env, target, findings, stats)
    return findings, stats


# ---------------------------------------------------------------------------
# Driver: the envelope proofs at MAX_GEOMETRY


def _max_geometry_cases():
    """(target, fn, arg ShapeDtypeStructs, seed intervals) at the
    MAX_GEOMETRY envelope.

    Contracted axes (clause pool C, literal words W, dense literals 2o,
    classes m) sit at the envelope; batch and patch axes are tiny because
    they are only ever OR-reduced or parallel — their extent never feeds
    an accumulator.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import clauses as cl
    from repro.core.cotm import MAX_GEOMETRY, WEIGHT_MAX, WEIGHT_MIN
    from repro.kernels import ref

    G = MAX_GEOMETRY
    C, m, L = G.n_clauses, G.n_classes, G.n_literals
    W = L // 32
    B, P = 4, 8  # parallel axes; see docstring
    u8, u32, i8 = jnp.uint8, jnp.uint32, jnp.int8
    S = jax.ShapeDtypeStruct
    bit = Interval(0, 1)
    word = Interval(0, (1 << 32) - 1)
    wt = Interval(WEIGHT_MIN, WEIGHT_MAX)

    def popcount_chain(lit_packed, exclude_packed):
        # jnp mirror of the sparse kernels' per-word accumulation
        # (clause_eval.py / fused_infer.py): sum of W popcounts into
        # int32.
        miss = ~(lit_packed[:, :, None, :] | exclude_packed[None, None])
        return jnp.sum(
            jax.lax.population_count(miss).astype(jnp.int32), axis=-1
        )

    def class_sum_tile_f32(fired, w):
        # fp32 accumulation tile of the Pallas class-sum/fused kernels
        # at the largest block_c (128): exactness needs 127 * 128 < 2^24.
        part = jax.lax.dot_general(
            fired.astype(jnp.float32), w.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return part.astype(jnp.int32)

    def train_eval(literals, include, weights):
        return cl.class_sums(
            cl.eval_clauses_matmul(literals, include), weights
        )

    return [
        ("ir:ref.class_sum", ref.class_sum_ref,
         [S((B, C), u8), S((m, C), i8)], [bit, wt]),
        ("ir:ref.clause_eval", ref.clause_eval_ref,
         [S((B, P, W), u32), S((C, W), u32), S((C,), u8)],
         [word, word, bit]),
        ("ir:ref.fused_infer", ref.fused_infer_ref,
         [S((B, P, W), u32), S((C, W), u32), S((C,), u8), S((m, C), i8)],
         [word, word, bit, wt]),
        ("ir:ref.matmul_sparse_infer", ref.matmul_sparse_infer_ref,
         [S((B, P, L), u8), S((C, L), u8), S((m, C), i8)],
         [bit, bit, wt]),
        ("ir:kernel.popcount_chain", popcount_chain,
         [S((B, P, W), u32), S((C, W), u32)], [word, word]),
        ("ir:kernel.class_sum_tile_f32", class_sum_tile_f32,
         [S((B, 128), u8), S((m, 128), i8)], [bit, wt]),
        ("ir:train.eval_matmul", train_eval,
         [S((B, P, L), u8), S((C, L), u8), S((m, C), i8)],
         [bit, bit, wt]),
    ]


def check_intervals(result: VerifyResult, baseline: Baseline) -> None:
    from repro.core.cotm import MAX_GEOMETRY

    lines = result.summary.setdefault("TM404", [])
    G = MAX_GEOMETRY
    lines.append(
        f"envelope: n_clauses={G.n_clauses} n_classes={G.n_classes} "
        f"n_literals={G.n_literals} n_patches={G.n_patches} "
        f"batch={G.batch}"
    )
    for target, fn, specs, seeds in _max_geometry_cases():
        result.checks += 1
        result.targets.append(target)
        findings, stats = analyze_fn(fn, specs, seeds, target)
        for f in findings:
            result.add(baseline, f)
        widest = stats.widest_int
        lines.append(
            f"{target}: {stats.eqns} eqns ({stats.handled} handled), "
            + (f"widest integer interval [{widest.lo}, {widest.hi}]"
               if widest else "no integer eqn outputs")
            + (f", {len(findings)} finding(s)" if findings else ", clean")
        )
