"""TM405: Pallas grid coverage and VMEM budget audit.

Intercepts every ``pl.pallas_call`` a kernel wrapper makes (monkeypatched
during ``jax.eval_shape`` — abstract evaluation, nothing compiles or
runs) and audits the captured launch geometry:

  * **grid coverage** — for every BlockSpec, the index map evaluated at
    the zero and corner grid points must place blocks covering the
    operand exactly: origin 0 at the zero point, ``(corner_index + 1) *
    block == extent`` per axis, and every extent a block multiple.  A
    grid computed from an unpadded extent silently drops the remainder
    tile; an oversized one reads out of bounds.
  * **VMEM budget** — resident footprint = sum of in/out block bytes
    x 2 (double buffering) + scratch bytes must fit a configurable
    budget (default 16 MiB per core, see
    ``/opt/skills/guides/pallas_guide.md``).

The audit drives the *unjitted* wrapper bodies (``fn.__wrapped__``) with
``backend='pallas'`` so the jit caches are never poisoned with the fake
kernel, the block-clamping arithmetic exercised is the exact
``kernels/shapes.py`` code dispatch uses, and the parameter sets swept
are ``serve.paths._KERNEL_TUNABLE`` — the autotuner's real candidates.

Index maps are affine in the repo (identity or pinned-to-0 per axis), so
zero/corner evaluation brackets the block origins exactly; a
non-monotone index map would need denser sampling, and none exists here.
"""

from __future__ import annotations

import contextlib
import dataclasses
import inspect
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from tools.tmverify.core import Baseline, Finding, VerifyResult
from tools.tmverify.targets import VerifyConfig

__all__ = [
    "PallasCapture",
    "audit_capture",
    "capture_pallas_calls",
    "check_pallas",
]


@dataclasses.dataclass
class PallasCapture:
    """One intercepted pallas_call launch."""

    label: str
    grid: Tuple[int, ...]
    in_specs: List                      # BlockSpec-likes (block_shape, index_map)
    out_specs: List
    out_shapes: List[Tuple[Tuple[int, ...], object]]   # (shape, dtype)
    scratch: List[Tuple[Tuple[int, ...], object]]
    operand_shapes: List[Tuple[int, ...]] = dataclasses.field(
        default_factory=list
    )


def _as_list(x) -> List:
    if x is None:
        return []
    if isinstance(x, (list, tuple)):
        return list(x)
    return [x]


def _shape_dtype(x) -> Tuple[Tuple[int, ...], object]:
    return tuple(int(d) for d in x.shape), x.dtype


@contextlib.contextmanager
def capture_pallas_calls(label: str = "?"):
    """Patch ``jax.experimental.pallas.pallas_call`` to record launch
    geometry and return abstract zeros; yields the capture list."""
    import jax.experimental.pallas as pl_mod
    import jax.numpy as jnp

    captures: List[PallasCapture] = []
    real = pl_mod.pallas_call

    def fake(kernel, *, grid=(), in_specs=None, out_specs=None,
             out_shape=None, scratch_shapes=(), **kwargs):
        g = (grid,) if isinstance(grid, int) else tuple(int(x) for x in grid)
        cap = PallasCapture(
            label=label,
            grid=g,
            in_specs=_as_list(in_specs),
            out_specs=_as_list(out_specs),
            out_shapes=[_shape_dtype(s) for s in _as_list(out_shape)],
            scratch=[_shape_dtype(s) for s in _as_list(scratch_shapes)],
        )
        captures.append(cap)

        single = out_shape is not None and not isinstance(
            out_shape, (list, tuple)
        )

        def runner(*args):
            cap.operand_shapes = [
                tuple(int(d) for d in a.shape) for a in args
            ]
            outs = [jnp.zeros(s, d) for s, d in cap.out_shapes]
            return outs[0] if single else tuple(outs)

        return runner

    pl_mod.pallas_call = fake
    try:
        yield captures
    finally:
        pl_mod.pallas_call = real


def _itemsize(dtype) -> int:
    return np.dtype(dtype).itemsize


def _block_bytes(block: Tuple[int, ...], dtype) -> int:
    n = 1
    for d in block:
        n *= int(d)
    return n * _itemsize(dtype)


def audit_capture(
    cap: PallasCapture, *, budget: int
) -> Tuple[List[Finding], int]:
    """Findings + resident VMEM footprint (bytes) for one launch."""
    findings: List[Finding] = []
    target = f"pallas:{cap.label}"
    zero_idx = (0,) * len(cap.grid)
    corner_idx = tuple(g - 1 for g in cap.grid)

    # Pair every spec with the shape/dtype it tiles.  Operand dtypes for
    # inputs are not recorded by the fake runner (tracers only expose
    # shape reliably pre-materialization), so input block bytes use the
    # matching out/scratch-free worst case: uint32 words dominate and
    # every kernel input here is <= 4 bytes/elem; we recover the true
    # dtype when the runner captured avals with dtypes.
    pairs = []
    for i, spec in enumerate(cap.in_specs):
        shape = (cap.operand_shapes[i]
                 if i < len(cap.operand_shapes) else None)
        pairs.append((f"in{i}", spec, shape, None))
    for i, spec in enumerate(cap.out_specs):
        shape, dtype = (cap.out_shapes[i]
                        if i < len(cap.out_shapes) else (None, None))
        pairs.append((f"out{i}", spec, shape, dtype))

    moving_bytes = 0
    for role, spec, shape, dtype in pairs:
        block = tuple(int(d) for d in spec.block_shape)
        if dtype is None:
            dtype = np.uint32  # conservative 4-byte elems for inputs
        moving_bytes += _block_bytes(block, dtype)
        if shape is None:
            continue
        if len(block) != len(shape):
            findings.append(Finding(
                "TM405", target, f"{role}:rank",
                f"{role}: block rank {len(block)} != operand rank "
                f"{len(shape)}",
            ))
            continue
        try:
            zero = spec.index_map(*zero_idx)
            corner = spec.index_map(*corner_idx)
        except TypeError:
            findings.append(Finding(
                "TM405", target, f"{role}:index-map-arity",
                f"{role}: index map does not accept the {len(cap.grid)}-d "
                f"grid index",
            ))
            continue
        zero = zero if isinstance(zero, tuple) else (zero,)
        corner = corner if isinstance(corner, tuple) else (corner,)
        for d, (b, ext) in enumerate(zip(block, shape)):
            if ext % b:
                findings.append(Finding(
                    "TM405", target, f"{role}:axis{d}:unpadded",
                    f"{role} axis {d}: extent {ext} is not a multiple of "
                    f"block {b} — remainder tile dropped or OOB",
                ))
                continue
            cover = (int(corner[d]) + 1) * b
            if int(zero[d]) != 0 or cover != ext:
                findings.append(Finding(
                    "TM405", target, f"{role}:axis{d}:cover",
                    f"{role} axis {d}: blocks cover [{int(zero[d]) * b}, "
                    f"{cover}) of extent {ext} — grid does not tile the "
                    f"padded operand exactly",
                ))

    scratch_bytes = sum(
        _block_bytes(s, d) for s, d in cap.scratch
    )
    footprint = 2 * moving_bytes + scratch_bytes
    if footprint > budget:
        findings.append(Finding(
            "TM405", target, f"vmem:{footprint}",
            f"resident footprint {footprint} B (2 x {moving_bytes} block "
            f"B + {scratch_bytes} scratch B) exceeds the VMEM budget "
            f"{budget} B",
        ))
    return findings, footprint


# ---------------------------------------------------------------------------
# Driver: every kernel wrapper at the MAX_GEOMETRY envelope


def _unjitted(fn):
    return getattr(fn, "__wrapped__", fn)


def _filter_kwargs(fn, kwargs: Dict) -> Dict:
    sig = inspect.signature(_unjitted(fn))
    return {k: v for k, v in kwargs.items() if k in sig.parameters}


def _envelope_cases():
    import jax
    import jax.numpy as jnp

    from repro.core.cotm import MAX_GEOMETRY
    from repro.core.patches import PatchSpec
    from repro.kernels import ops

    G = MAX_GEOMETRY
    B, P, C, m = G.batch, G.n_patches, G.n_clauses, G.n_classes
    W = G.n_literals // 32
    S = jax.ShapeDtypeStruct
    u8, u32, i8 = jnp.uint8, jnp.uint32, jnp.int8

    lit = S((B, P, W), u32)
    inc = S((C, W), u32)
    ne = S((C,), u8)
    wts = S((m, C), i8)
    fired = S((B, C), u8)

    cases = [
        ("clause_eval", ops.clause_eval, (lit, inc, ne), {}),
        ("class_sum", ops.class_sum, (fired, wts), {}),
        ("fused_infer", ops.fused_infer, (lit, inc, ne, wts), {}),
        ("clause_eval_sparse", ops.clause_eval_sparse, (lit, inc), {}),
        ("fused_infer_sparse", ops.fused_infer_sparse, (lit, inc, wts), {}),
    ]
    # Ingress runs at the shipped image geometries (its VMEM use is set
    # by the real patch specs, not the clause-pool envelope).  The spec
    # is a static kwarg: eval_shape must not see it as a traced operand.
    for tag, spec in (
        ("mnist", PatchSpec(28, 28, 10, 10)),
        ("cifar3x3", PatchSpec(32, 32, 3, 3)),
    ):
        cases.append((
            f"ingress_pack:{tag}", ops.ingress_pack,
            (S((B, spec.image_y, spec.image_x), u8),),
            {"spec": spec},
        ))
    return cases


def check_pallas(
    vcfg: VerifyConfig, result: VerifyResult, baseline: Baseline
) -> None:
    import jax

    from repro.serve.paths import _KERNEL_TUNABLE

    lines = result.summary.setdefault("TM405", [])
    lines.append(
        f"budget: {vcfg.vmem_budget} B; param sets: "
        f"{len(_KERNEL_TUNABLE)} (serve.paths._KERNEL_TUNABLE)"
    )
    worst = 0
    worst_label = ""
    for name, fn, args, extra in _envelope_cases():
        # Distinct kwarg sets only: a repeat would hit the inner pallas
        # fn's jit cache, re-using the already-captured trace and
        # falsely reporting "no launch".
        seen_kw = set()
        for params in _KERNEL_TUNABLE:
            kw = _filter_kwargs(fn, dict(params))
            if tuple(sorted(kw.items())) in seen_kw:
                continue
            seen_kw.add(tuple(sorted(kw.items())))
            kw.update(extra)
            kw["backend"] = "pallas"
            slug = ",".join(f"{k}={v}" for k, v in sorted(kw.items())
                            if k not in ("backend", "spec")) or "defaults"
            label = f"{name}[{slug}]"
            result.checks += 1
            with capture_pallas_calls(label) as caps:
                jax.eval_shape(lambda *a: _unjitted(fn)(*a, **kw), *args)
            if not caps:
                result.add(baseline, Finding(
                    "TM405", f"pallas:{label}", "no-launch",
                    "backend='pallas' produced no pallas_call — the "
                    "kernel route silently fell back",
                ))
                continue
            for cap in caps:
                result.targets.append(f"pallas:{cap.label}")
                findings, footprint = audit_capture(
                    cap, budget=vcfg.vmem_budget
                )
                for f in findings:
                    result.add(baseline, f)
                if footprint > worst:
                    worst, worst_label = footprint, cap.label
        lines.append(f"{name}: all param sets launch-audited")
    lines.append(
        f"worst resident footprint: {worst} B ({worst_label}), "
        f"{100 * worst / vcfg.vmem_budget:.1f}% of budget"
    )
    # The inner pallas fns' jit caches now hold traces of the fake
    # pallas_call (zeros); drop them so nothing downstream can reuse one.
    jax.clear_caches()
