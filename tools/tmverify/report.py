"""Deterministic markdown report for a verify run (REPORT.md)."""

from __future__ import annotations

from tools.tmverify.core import RULE_DOCS, VerifyResult
from tools.tmverify.targets import VerifyConfig

__all__ = ["render_report"]

_REGEN = "python -m tools.tmverify src/repro --report > tools/tmverify/REPORT.md"


def render_report(result: VerifyResult, vcfg: VerifyConfig) -> str:
    import jax

    lines = [
        "# tmverify report",
        "",
        "IR-level contract verification of every jitted serve/train "
        "step (see `tools/tmverify/__init__.py` for the rule "
        "rationale).  Committed and freshness-gated by "
        "`tests/test_tmverify.py`; regenerate with:",
        "",
        "```",
        _REGEN,
        "```",
        "",
        f"- backend: `{jax.default_backend()}`",
        f"- targets verified: {len(result.targets)}",
        f"- checks evaluated: {result.checks}",
        f"- findings: {len(result.findings)} "
        f"(suppressed by baseline: {len(result.suppressed)}, "
        f"stale waivers: {len(result.stale_baseline)})",
        f"- serve bucket range: 1..{vcfg.max_batch} "
        f"(engine max_batch for TM403 counts: {vcfg.engine_max_batch})",
        f"- VMEM budget (TM405): {vcfg.vmem_budget} B",
        "",
        "## Rules",
        "",
    ]
    for rule in sorted(RULE_DOCS):
        lines.append(f"- **{rule}** — {RULE_DOCS[rule]}")
    for rule in sorted(result.summary):
        lines += ["", f"## {rule}", ""]
        lines += [f"- {ln}" for ln in result.summary[rule]]
    lines += ["", "## Findings", ""]
    if result.findings:
        lines += [f"- {f.render()}" for f in result.findings]
    else:
        lines.append("*(none)*")
    lines += ["", "## Suppressed by baseline", ""]
    if result.suppressed:
        lines += [f"- {f.render()}" for f in result.suppressed]
    else:
        lines.append("*(none)*")
    lines += ["", "## Verified targets", ""]
    lines += [f"- `{t}`" for t in result.targets]
    lines.append("")
    return "\n".join(lines)
