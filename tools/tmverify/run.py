"""Top-level verify runner: enumerate targets, run TM401-TM405."""

from __future__ import annotations

from tools.tmverify.core import Baseline, VerifyResult
from tools.tmverify.targets import VerifyConfig

__all__ = ["run_verify"]


def run_verify(vcfg: VerifyConfig, baseline: Baseline) -> VerifyResult:
    from tools.tmverify.analyses import (
        check_donation,
        check_host_transfers,
        check_recompile_keys,
    )
    from tools.tmverify.intervals import check_intervals
    from tools.tmverify.pallas_check import check_pallas
    from tools.tmverify.targets import enumerate_targets

    result = VerifyResult(
        findings=[], suppressed=[], stale_baseline=[], targets=[], checks=0
    )
    steps = enumerate_targets(vcfg)
    result.targets.extend(t.name for t in steps)
    check_donation(steps, result, baseline)
    check_host_transfers(steps, result, baseline)
    check_recompile_keys(vcfg, result, baseline)
    check_intervals(result, baseline)     # appends its ir:* targets
    check_pallas(vcfg, result, baseline)  # appends its pallas:* targets
    result.stale_baseline = baseline.stale_entries()
    return result
