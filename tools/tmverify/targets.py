"""Enumerate the verify targets: every jitted step the serving and
training engines can dispatch.

Serve targets are the cross product (registered EvalPath) x (input form:
literals | raw) x (pow2 bucket), traced through the *actual* module-level
jit wrappers (``serve.engine.classify_step`` / ``raw_step_jit()``) so the
static keys, donation declarations and ingress fusion audited are the
ones dispatch uses — not reconstructions.  The train target is the
``TrainerEngine`` epoch step (one jitted ``lax.scan`` with the model
buffers donated).

Tracing happens at a tiny geometry: every analysis here is shape-generic
(primitive sets, aliasing attributes, static-key structure), so the tiny
trace is the cheap witness; the geometry-*dependent* proofs (TM404
overflow, TM405 VMEM budgets) run separately at
``repro.core.cotm.MAX_GEOMETRY`` — see ``intervals.py`` /
``pallas_check.py``.

All repro imports are function-local so ``tools.tmverify.__main__`` can
fix ``sys.path`` before anything touches the package.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

__all__ = ["StepTarget", "VerifyConfig", "enumerate_targets", "buckets_for"]


@dataclasses.dataclass
class VerifyConfig:
    """Knobs for a verify run (CLI flags in ``__main__``)."""

    max_batch: int = 32                    # serve bucket range endpoint
    engine_max_batch: int = 256            # engine default, for TM403 counts
    vmem_budget: int = 16 * 1024 * 1024    # TM405 resident-footprint budget
    cardinality_cap: int = 128             # TM403 cache keys per (path, form)


@dataclasses.dataclass
class StepTarget:
    """One lowered jitted step under audit."""

    name: str                  # e.g. "serve:fused:raw:b8" / "train:epoch"
    kind: str                  # "serve" | "train"
    path_name: Optional[str]
    form: Optional[str]        # "literals" | "raw" | None (train)
    bucket: Optional[int]
    jaxpr: object              # ClosedJaxpr of the whole step
    donated_leaves: int        # leaves declared donated (0 = none declared)
    traced: object             # jax stages Traced (lower() on demand)

    def lowered_text(self) -> str:
        return self.traced.lower().as_text()


def buckets_for(max_batch: int) -> Tuple[int, ...]:
    """Every pow2 bucket the engine can dispatch: 1, 2, ..., max_batch."""
    out, b = [], 1
    while b < max_batch:
        out.append(b)
        b <<= 1
    out.append(max_batch)
    return tuple(out)


def tiny_config():
    """The trace geometry: small, nondegenerate, fast to trace."""
    from repro.core.cotm import CoTMConfig
    from repro.core.patches import PatchSpec

    spec = PatchSpec(image_x=8, image_y=8, window_x=4, window_y=4)
    return CoTMConfig(n_clauses=8, n_classes=3, patch=spec, T=20)


def _declared_donations(jit_fn) -> Optional[Tuple[int, ...]]:
    """The donate_argnums a jit wrapper was built with, introspected from
    the wrapper itself (None when the wrapper does not expose them)."""
    info = getattr(jit_fn, "_jit_info", None)
    donate = getattr(info, "donate_argnums", None)
    if donate is not None:
        return tuple(donate)
    return None


def _tiny_servable():
    import jax

    from repro.core.cotm import init_boundary_model
    from repro.serve.servable import analyze_sparsity, freeze

    cfg = tiny_config()
    model = init_boundary_model(jax.random.PRNGKey(0), cfg)
    return cfg, analyze_sparsity(freeze(model, cfg))


def enumerate_serve_targets(vcfg: VerifyConfig) -> List[StepTarget]:
    import jax
    import jax.numpy as jnp

    from repro.core.ingress import raw_trailing_shape
    from repro.serve import engine as se
    from repro.serve.paths import PACKED, available_paths, get_path

    cfg, servable = _tiny_servable()
    spec = cfg.patch
    raw_jit = se.raw_step_jit()
    raw_donate = _declared_donations(raw_jit)
    if raw_donate is None:
        # Wrapper introspection unavailable: fall back to the engine's
        # documented declaration (donate raw everywhere but CPU).
        raw_donate = () if jax.default_backend() == "cpu" else (1,)

    targets: List[StepTarget] = []
    for name in available_paths():
        path = get_path(name)
        ingress = path.ingress_spec(spec)
        for bucket in buckets_for(vcfg.max_batch):
            if path.input_form == PACKED:
                lits = jax.ShapeDtypeStruct(
                    (bucket, spec.n_patches, spec.n_words), jnp.uint32
                )
            else:
                lits = jax.ShapeDtypeStruct(
                    (bucket, spec.n_patches, spec.n_literals), jnp.uint8
                )
            tr = se.classify_step.trace(
                servable, lits, path_name=name, params=()
            )
            targets.append(StepTarget(
                name=f"serve:{name}:literals:b{bucket}",
                kind="serve", path_name=name, form="literals", bucket=bucket,
                jaxpr=tr.jaxpr, donated_leaves=0, traced=tr,
            ))

            raw = jax.ShapeDtypeStruct(
                (bucket,) + raw_trailing_shape(ingress), jnp.uint8
            )
            tr = raw_jit.trace(
                servable, raw, path_name=name, ingress=ingress, params=()
            )
            targets.append(StepTarget(
                name=f"serve:{name}:raw:b{bucket}",
                kind="serve", path_name=name, form="raw", bucket=bucket,
                jaxpr=tr.jaxpr,
                donated_leaves=1 if 1 in raw_donate else 0,
                traced=tr,
            ))
    return targets


def trainer_target() -> StepTarget:
    import jax
    import jax.numpy as jnp

    from repro.train.tm_engine import TrainerEngine

    cfg = tiny_config()
    engine = TrainerEngine(cfg, batch_size=4)
    model = engine.init_model(jax.random.PRNGKey(0))
    n, steps, batch = 8, 2, 4
    lits = jnp.zeros((n, cfg.patch.n_patches, cfg.n_literals), jnp.uint8)
    labels = jnp.zeros((n,), jnp.int32)
    idx = jnp.zeros((steps, batch), jnp.int32)
    _, keys = engine._chain_keys(jax.random.PRNGKey(1), steps)
    tr = engine._epoch_fn.trace(model, lits, labels, idx, keys)
    donate = _declared_donations(engine._epoch_fn) or (0,)
    donated_leaves = (
        len(jax.tree_util.tree_leaves(model)) if 0 in donate else 0
    )
    return StepTarget(
        name="train:epoch", kind="train", path_name=None, form=None,
        bucket=None, jaxpr=tr.jaxpr, donated_leaves=donated_leaves,
        traced=tr,
    )


def enumerate_targets(vcfg: VerifyConfig) -> List[StepTarget]:
    return enumerate_serve_targets(vcfg) + [trainer_target()]
